//! Losses: softmax cross-entropy (the framework is "totally compatible
//! with the functions in PyTorch, such as the loss function" — here the
//! digital loss head lives outside the hardware layers).

use crate::tensor::Tensor;

/// Softmax cross-entropy over logits `(B, C)` with integer labels.
/// Returns `(mean_loss, grad_logits)`.
pub fn softmax_cross_entropy(logits: &Tensor, labels: &[usize]) -> (f64, Tensor) {
    assert_eq!(logits.shape.len(), 2);
    let (b, c) = (logits.shape[0], logits.shape[1]);
    assert_eq!(labels.len(), b);
    let mut grad = Tensor::zeros(&logits.shape);
    let mut loss = 0.0;
    for i in 0..b {
        let row = &logits.data[i * c..(i + 1) * c];
        let max = row.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let exps: Vec<f64> = row.iter().map(|&v| (v - max).exp()).collect();
        let sum: f64 = exps.iter().sum();
        let label = labels[i];
        assert!(label < c, "label {label} out of range");
        loss += -(exps[label] / sum).max(1e-300).ln();
        for j in 0..c {
            let p = exps[j] / sum;
            grad.data[i * c + j] = (p - if j == label { 1.0 } else { 0.0 }) / b as f64;
        }
    }
    (loss / b as f64, grad)
}

/// Classification accuracy of logits vs labels.
pub fn accuracy(logits: &Tensor, labels: &[usize]) -> f64 {
    let (b, c) = (logits.shape[0], logits.shape[1]);
    let correct = (0..b)
        .filter(|&i| {
            let row = &logits.data[i * c..(i + 1) * c];
            let argmax = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .map(|(j, _)| j)
                .unwrap();
            argmax == labels[i]
        })
        .count();
    correct as f64 / b as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_logits_loss_is_log_c() {
        let logits = Tensor::zeros(&[4, 10]);
        let (loss, _) = softmax_cross_entropy(&logits, &[0, 3, 5, 9]);
        assert!((loss - (10f64).ln()).abs() < 1e-12);
    }

    #[test]
    fn grad_sums_to_zero_per_row() {
        let logits = Tensor::from_vec(&[2, 3], vec![1.0, 2.0, 3.0, -1.0, 0.0, 1.0]);
        let (_, g) = softmax_cross_entropy(&logits, &[0, 2]);
        for i in 0..2 {
            let s: f64 = g.data[i * 3..(i + 1) * 3].iter().sum();
            assert!(s.abs() < 1e-12);
        }
    }

    #[test]
    fn gradcheck() {
        let logits = Tensor::from_vec(&[2, 4], vec![0.3, -1.2, 0.7, 0.1, 2.0, 0.5, -0.5, 0.0]);
        let labels = [2usize, 0];
        let (_, g) = softmax_cross_entropy(&logits, &labels);
        for idx in 0..8 {
            let mut lp = logits.clone();
            lp.data[idx] += 1e-6;
            let mut lm = logits.clone();
            lm.data[idx] -= 1e-6;
            let (fp, _) = softmax_cross_entropy(&lp, &labels);
            let (fm, _) = softmax_cross_entropy(&lm, &labels);
            let want = (fp - fm) / 2e-6;
            assert!((g.data[idx] - want).abs() < 1e-6, "idx {idx}");
        }
    }

    #[test]
    fn perfect_prediction_low_loss_full_accuracy() {
        let mut logits = Tensor::zeros(&[3, 3]);
        for i in 0..3 {
            logits.data[i * 3 + i] = 20.0;
        }
        let (loss, _) = softmax_cross_entropy(&logits, &[0, 1, 2]);
        assert!(loss < 1e-6);
        assert_eq!(accuracy(&logits, &[0, 1, 2]), 1.0);
        assert!((accuracy(&logits, &[1, 1, 2]) - 2.0 / 3.0).abs() < 1e-12);
    }
}

//! [`MemCore`] — the shared hardware state of every DPE-backed layer.
//!
//! `LinearMem` and `Conv2dMem` used to each carry their own copy of the
//! `Option<HwSpec>` + prepared-weight + generation + input-cache plumbing;
//! this struct owns all of it once, and adds the chip-mapping state: which
//! physical array slots the layer's weight blocks occupy, and therefore
//! which RNG streams their programming noise, fault masks, and ADC chains
//! draw from ([`crate::dpe::DotProductEngine::prepare_weights_mapped`]).
//!
//! Stream assignment has two sources:
//! - [`MemCore::set_contiguous_base`] — the *virtual* layer-order packing
//!   a [`super::Sequential`] applies at construction (block `b` of a layer
//!   whose planes start at `base` gets stream `base + b·S_w`);
//! - [`MemCore::set_block_streams`] — an explicit per-block slot list from
//!   a chip compile ([`crate::arch::TileAllocator`]), which may be
//!   non-contiguous when block groups spilled across tiles.
//!
//! A single-tile chip packed in layer order produces exactly the virtual
//! streams, which is what makes the mapped and unmapped paths
//! bit-identical there (the anchor).

use super::HwSpec;
use crate::arch::{BlockMove, LayerPlacement};
use crate::dpe::blocks::MatmulBlocks;
use crate::dpe::{
    DeltaReport, PreparedInputs, PreparedWeights, ProgramReport, RepairSpec, WeightTemplate,
};
use crate::tensor::Matrix;
use crate::util::parallel::par_map;

/// Shared hardware-layer state: engine binding, programmed weight copy,
/// programming generation, physical-slot streams, and the opt-in input
/// cache. See the module docs.
pub struct MemCore {
    hw: Option<HwSpec>,
    prepared: Option<PreparedWeights>,
    /// Weight-programming generation (decorrelates programming noise).
    generation: u64,
    /// First-plane slot id of the virtual contiguous packing (0 for a
    /// standalone layer).
    plane_base: u64,
    /// Explicit per-block streams from a chip compile; overrides
    /// `plane_base` when set.
    assigned_streams: Option<Vec<u64>>,
    /// Placement record (compiled models only) — surfaced by
    /// [`super::Sequential::summary`].
    placement: Option<LayerPlacement>,
    /// Opt-in cached-input eval path (see [`MemCore::set_input_caching`]).
    cache_inputs_enabled: bool,
    /// `(input key, its prepared slicing)` — valid while the key matches;
    /// deliberately NOT cleared by reprogramming (input slicing is
    /// weight-independent, which is exactly what makes re-evaluating a
    /// fixed batch across programming cycles cheap).
    input_cache: Option<(Vec<f64>, PreparedInputs)>,
    /// The full-precision weight matrix last programmed — the ground
    /// truth the repair loop needs: verified reprogramming re-derives the
    /// template from it, health probes compute their checksum
    /// expectations from it, and remap-to-spare reprograms moved blocks
    /// from it ([`crate::arch::repair`]).
    last_w: Option<Matrix>,
    /// Block groups fenced off by [`MemCore::condemn_blocks`] (degraded
    /// mode: contribute exactly zero). Cleared whenever the core is fully
    /// reprogrammed — a rewrite re-materializes every group.
    condemned: Vec<usize>,
    /// Quantized digit baseline of the *currently programmed* weights —
    /// what [`MemCore::program_delta`] diffs each optimizer step against
    /// (`dpe::engine` §Perf training path). Invalidated whenever the
    /// programmed bits are rewritten outside the delta path (a full
    /// [`MemCore::reprogram`]); remaps and verified reprogramming keep it
    /// valid because they re-derive the same digits from `last_w`.
    template: Option<WeightTemplate>,
    /// Cumulative programming accounting across this core's lifetime:
    /// every full reprogram merges [`DeltaReport::full`], every delta pass
    /// merges its own report. The fig16 bench asserts from these that a
    /// step touching one layer redraws only that layer's dirty blocks.
    program_stats: DeltaReport,
    /// Memoized output of [`MemCore::matmul_from_cache`], keyed by the
    /// programming generation it was computed at — an eval-with-caching
    /// loop interleaved with training steps must re-run the matmul against
    /// freshly programmed weights, never serve a stale output. Invalidated
    /// whenever the prepared weights change (any reprogram/remap/condemn,
    /// delta passes included) or the input cache is refilled.
    output_memo: Option<(u64, Matrix)>,
}

impl MemCore {
    pub fn new(hw: Option<HwSpec>) -> Self {
        MemCore {
            hw,
            prepared: None,
            generation: 0,
            plane_base: 0,
            assigned_streams: None,
            placement: None,
            cache_inputs_enabled: false,
            input_cache: None,
            last_w: None,
            condemned: Vec::new(),
            template: None,
            program_stats: DeltaReport::default(),
            output_memo: None,
        }
    }

    pub fn hw(&self) -> Option<&HwSpec> {
        self.hw.as_ref()
    }

    pub fn is_prepared(&self) -> bool {
        self.prepared.is_some()
    }

    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// Opt into caching the quantized + sliced input across eval-mode
    /// forward calls (hardware path only): when the same batch is
    /// evaluated repeatedly — e.g. Monte-Carlo over reprogramming cycles —
    /// the DPE then pays only the matmul cost per call. Keyed on exact
    /// input equality and bit-identical to the uncached path. Off by
    /// default.
    pub fn set_input_caching(&mut self, on: bool) {
        self.cache_inputs_enabled = on;
        if !on {
            self.input_cache = None;
            self.output_memo = None;
        }
    }

    pub fn input_caching_enabled(&self) -> bool {
        self.cache_inputs_enabled
    }

    /// The per-block programming streams for a weight grid of `blocks`
    /// pairs with `slices` planes each: the compiled slot list when
    /// assigned, else the virtual contiguous packing.
    fn block_streams(&self, blocks: usize, slices: usize) -> Vec<u64> {
        match &self.assigned_streams {
            Some(v) => {
                assert_eq!(
                    v.len(),
                    blocks,
                    "chip placement covers {} blocks, weight grid has {blocks}",
                    v.len()
                );
                v.clone()
            }
            None => (0..blocks as u64).map(|b| self.plane_base + b * slices as u64).collect(),
        }
    }

    /// Program the hardware copy from the full-precision weights,
    /// advancing the programming generation (the paper's
    /// `update_weight()`). No-op for digital layers.
    pub fn program(&mut self, w: &Matrix) {
        if self.hw.is_some() {
            self.generation += 1;
            self.reprogram(w);
        }
    }

    /// Re-derive the programmed copy at the **current** generation — used
    /// after slot (re)assignment, where the noise must change because the
    /// streams did, not because the weights were rewritten.
    pub fn reprogram(&mut self, w: &Matrix) {
        let Some(hw) = &self.hw else { return };
        if self.generation == 0 {
            return; // never programmed yet (constructor calls program()).
        }
        let grid = MatmulBlocks::new(w.rows, w.cols, hw.engine.cfg.array);
        let slices = hw.weight_method.spec.num_slices();
        let streams = self.block_streams(grid.pair_count(), slices);
        self.prepared = Some(hw.engine.prepare_weights_mapped(
            w,
            &hw.weight_method,
            self.generation,
            &streams,
        ));
        self.last_w = Some(w.clone());
        self.condemned.clear();
        // The delta baseline no longer matches the rewritten bits and the
        // memoized cached-input output is stale.
        self.template = None;
        self.output_memo = None;
        self.program_stats.merge(&DeltaReport::full(grid.pair_count()));
    }

    /// Delta-program the hardware copy from the updated full-precision
    /// weights, advancing the programming generation — the training-loop
    /// counterpart of [`MemCore::program`] (`dpe::engine` §Perf training
    /// path): only blocks whose quantized digits changed since the last
    /// programming are touched, and within them only the dirty cells are
    /// re-pulsed, at the block's existing per-slot stream. Falls back to a
    /// full reprogram (and reports it as such) when no digit baseline is
    /// cached yet, the weight shape changed, or program-time
    /// fault/retention injection is active (fault masks cannot be replayed
    /// cell-wise). Condemned blocks stay fenced off across delta passes.
    /// No-op (default report) for digital layers. On noise-free engines
    /// the programmed bits are identical to [`MemCore::program`]'s.
    pub fn program_delta(&mut self, w: &Matrix) -> DeltaReport {
        if self.hw.is_none() {
            return DeltaReport::default();
        }
        self.generation += 1;
        self.output_memo = None;
        let hw = self.hw.as_ref().expect("checked above");
        let engine = &hw.engine;
        let inject = !engine.cfg.noise_free && engine.cfg.nonideal.injects_at_program();
        let delta_ok = !inject
            && matches!(
                (&self.template, &self.prepared),
                (Some(t), Some(p)) if t.shape() == (w.rows, w.cols)
                    && p.shape() == (w.rows, w.cols)
                    && t.method() == &hw.weight_method
            );
        let grid = MatmulBlocks::new(w.rows, w.cols, engine.cfg.array);
        let slices = hw.weight_method.spec.num_slices();
        let streams = self.block_streams(grid.pair_count(), slices);
        let report = if delta_ok {
            let template = self.template.as_mut().expect("delta_ok implies template");
            let prep = self.prepared.as_mut().expect("delta_ok implies prepared");
            let report = engine.program_delta(template, w, self.generation, &streams, prep);
            // A delta apply may resurrect a condemned block's recombination
            // scale — re-fence them (sticky until a full rewrite).
            for &b in &self.condemned {
                prep.condemn_block(b);
            }
            report
        } else {
            self.template = Some(engine.weight_template(w, &hw.weight_method));
            self.prepared = Some(engine.prepare_weights_mapped(
                w,
                &hw.weight_method,
                self.generation,
                &streams,
            ));
            self.condemned.clear();
            DeltaReport::full(grid.pair_count())
        };
        self.last_w = Some(w.clone());
        self.program_stats.merge(&report);
        report
    }

    /// Cumulative programming accounting (full reprograms + delta passes)
    /// across this core's lifetime — the program-call counters the fig16
    /// bench and the delta regression tests assert against.
    pub fn program_stats(&self) -> DeltaReport {
        self.program_stats
    }

    /// Re-program the hardware copy through the program-and-verify loop
    /// ([`crate::dpe::WeightTemplate::program_verified_mapped`]) at the
    /// current generation and streams, returning the per-block
    /// retry/convergence accounting. `None` for digital or never-programmed
    /// cores. With `spec.verify == false` the programmed bits are
    /// identical to [`MemCore::reprogram`]'s.
    pub fn program_verified(&mut self, spec: &RepairSpec) -> Option<ProgramReport> {
        let hw = self.hw.as_ref()?;
        let w = self.last_w.as_ref()?;
        if self.generation == 0 {
            return None;
        }
        let template = hw.engine.weight_template(w, &hw.weight_method);
        let grid = MatmulBlocks::new(w.rows, w.cols, hw.engine.cfg.array);
        let slices = hw.weight_method.spec.num_slices();
        let streams = self.block_streams(grid.pair_count(), slices);
        let (prep, report) =
            template.program_verified_mapped(&hw.engine, self.generation, spec, &streams);
        self.prepared = Some(prep);
        self.condemned.clear();
        self.output_memo = None;
        Some(report)
    }

    /// Fence off block groups in degraded mode
    /// ([`crate::dpe::PreparedWeights::condemn_block`]): each listed group's
    /// recombination scale is zeroed so it contributes exactly zero to
    /// every matmul — bounded missing-contribution error instead of
    /// unbounded stuck-at readout garbage. Sticky until the core is
    /// reprogrammed (or the block is remapped to a fresh slot). Returns
    /// whether anything was condemned.
    pub fn condemn_blocks(&mut self, blocks: &[usize]) -> bool {
        let Some(prep) = self.prepared.as_mut() else { return false };
        let mut any = false;
        for &b in blocks {
            prep.condemn_block(b);
            if !self.condemned.contains(&b) {
                self.condemned.push(b);
            }
            any = true;
        }
        self.condemned.sort_unstable();
        if any {
            self.output_memo = None;
        }
        any
    }

    /// Block groups currently fenced off (sorted). Surfaced by
    /// [`crate::nn::Sequential::summary`] as a per-layer `condemned=` count.
    pub fn condemned_blocks(&self) -> &[usize] {
        &self.condemned
    }

    /// Health-probe every placed block group through the genuine fused
    /// GEMM path, without ground-truth activations: for each k-block, a
    /// deterministic checksum input (all-ones; optionally alternating ±1)
    /// that is zero outside that k-range — every other k-block quantizes
    /// to scale 0 and contributes *exactly* zero — is run through
    /// [`crate::dpe::DotProductEngine::matmul_prepared`] and compared
    /// against the digitally-computed expectation. Returns per-block
    /// relative-error scores (indexed `kb * n_blocks + nb`, matching the
    /// placement's block order) and the number of probe matmuls executed.
    pub fn probe_block_scores(&self, spec: &RepairSpec) -> Option<(Vec<f64>, usize)> {
        let hw = self.hw.as_ref()?;
        let prep = self.prepared.as_ref()?;
        let w = self.last_w.as_ref()?;
        let grid = MatmulBlocks::new(w.rows, w.cols, hw.engine.cfg.array);
        let nc = grid.n.count();
        let nv = spec.probe_vectors.clamp(1, 2);
        let mut scores = vec![0.0f64; grid.pair_count()];
        for kb in 0..grid.k.count() {
            let (k0, kl) = grid.k.range(kb);
            let probe = Matrix::from_fn(nv, w.rows, |v, j| {
                if j < k0 || j >= k0 + kl {
                    0.0
                } else if v == 0 || j % 2 == 0 {
                    1.0
                } else {
                    -1.0
                }
            });
            let got = hw.engine.matmul_prepared(&probe, prep, &hw.input_method, self.generation);
            let want = probe.matmul(w);
            for nb in 0..nc {
                let (n0, nl) = grid.n.range(nb);
                let (mut num, mut den) = (0.0f64, 0.0f64);
                for v in 0..nv {
                    for j in n0..n0 + nl {
                        let d = got.at(v, j) - want.at(v, j);
                        num += d * d;
                        den += want.at(v, j) * want.at(v, j);
                    }
                }
                scores[kb * nc + nb] = if den > 0.0 { (num / den).sqrt() } else { num.sqrt() };
            }
        }
        Some((scores, grid.k.count() * nv))
    }

    /// Apply remap-to-spare moves: reprogram the listed blocks at their
    /// new physical streams
    /// ([`crate::dpe::DotProductEngine::reprogram_prepared_blocks`] — the
    /// moved blocks' programming noise, fault masks, and ADC chains all
    /// come from the destination slots) and update the stream list and
    /// placement record to match. Returns whether anything moved.
    pub fn remap_blocks(&mut self, moves: &[&BlockMove]) -> bool {
        if moves.is_empty() {
            return false;
        }
        let Some(hw) = &self.hw else { return false };
        let Some(w) = &self.last_w else { return false };
        let Some(prep) = self.prepared.as_mut() else { return false };
        let slices = hw.weight_method.spec.num_slices();
        let base = self.plane_base;
        let mut streams = match &self.assigned_streams {
            Some(v) => v.clone(),
            None => {
                (0..prep.num_blocks() as u64).map(|b| base + b * slices as u64).collect()
            }
        };
        let pairs: Vec<(usize, u64)> = moves.iter().map(|m| (m.block, m.new_stream)).collect();
        hw.engine.reprogram_prepared_blocks(prep, w, &pairs, self.generation);
        self.output_memo = None;
        // A moved block is rewritten at its destination slot — it is no
        // longer fenced off.
        self.condemned.retain(|b| !pairs.iter().any(|(mb, _)| mb == b));
        for m in moves {
            streams[m.block] = m.new_stream;
            if let Some(lp) = self.placement.as_mut() {
                assert_eq!(m.to.len(), slices, "move slot count != group slice count");
                lp.block_streams[m.block] = m.new_stream;
                lp.slots[m.block * slices..(m.block + 1) * slices].copy_from_slice(&m.to);
            }
        }
        self.assigned_streams = Some(streams);
        true
    }

    /// Set the virtual contiguous stream base (layer-order packing).
    /// Returns whether it changed — the caller reprograms if so. Clears
    /// any compiled per-block assignment.
    pub fn set_contiguous_base(&mut self, base: u64) -> bool {
        let changed = self.plane_base != base || self.assigned_streams.is_some();
        self.plane_base = base;
        self.assigned_streams = None;
        self.placement = None;
        changed
    }

    /// Adopt a compiled chip placement: per-block physical slot streams.
    /// Returns whether the effective streams changed — when they match the
    /// current derivation (e.g. a single-tile layer-order compile
    /// reproducing the virtual packing), the arrays already hold exactly
    /// the bits a reprogram would produce and the caller skips it.
    pub fn set_block_streams(&mut self, placement: LayerPlacement) -> bool {
        let current = self.block_streams(placement.blocks, placement.slices);
        let changed = current != placement.block_streams;
        self.assigned_streams = Some(placement.block_streams.clone());
        self.placement = Some(placement);
        changed
    }

    pub fn placement(&self) -> Option<&LayerPlacement> {
        self.placement.as_ref()
    }

    /// `(block pairs, slices per block)` of the programmed weight grid —
    /// the chip-mapping demand. `None` for digital layers.
    pub fn demand(&self) -> Option<(usize, usize)> {
        let hw = self.hw.as_ref()?;
        let p = self.prepared.as_ref()?;
        Some((p.num_blocks(), hw.weight_method.spec.num_slices()))
    }

    /// Physical arrays used by this core (blocks × slices), once
    /// programmed.
    pub fn arrays_used(&self) -> Option<usize> {
        self.prepared.as_ref().map(PreparedWeights::arrays_used)
    }

    // ------------------------------------------------------ matmul paths

    /// Hardware matmul of the full input (engine-internal parallelism) —
    /// the eval/training forward path. `None` when the layer is digital.
    /// Small-`m` calls (single-sample [`crate::arch::MappedModel::infer`])
    /// still fill the worker pool: the DPE dispatches over (kb, nb) array
    /// pairs by total work, and a lone big pair 2-D-schedules its stacked
    /// GEMM over (row-band × panel-group) items (`dpe::engine` §Perf).
    /// On noise-free hardware the stacked GEMM additionally runs in the
    /// exact integer-domain kernel (byte panels, `i32`/`i64` accumulators,
    /// bit-identical to the f64 path) — picked per block at program time,
    /// no layer-level knob.
    pub fn matmul_eval(&self, x: &Matrix) -> Option<Matrix> {
        let hw = self.hw.as_ref()?;
        let prep = self.prepared.as_ref()?;
        Some(hw.engine.matmul_prepared(x, prep, &hw.input_method, self.generation))
    }

    /// Whether the input cache currently holds `key`.
    pub fn input_cache_hit(&self, key: &[f64]) -> bool {
        matches!(&self.input_cache, Some((k, _)) if k == key)
    }

    /// Fill the input cache: slice `m` once and file it under `key` (the
    /// raw layer input for Conv2dMem, the input matrix itself for
    /// LinearMem — a hit then skips im2col/stacking too).
    pub fn cache_inputs(&mut self, key: Vec<f64>, m: &Matrix) {
        let Some(hw) = &self.hw else { return };
        let ai = hw.engine.prepare_inputs(m, &hw.input_method);
        self.input_cache = Some((key, ai));
        self.output_memo = None;
    }

    /// Hardware matmul against the cached prepared inputs — bit-identical
    /// to [`MemCore::matmul_eval`] on the matrix the cache was filled
    /// with. `None` when digital, unprepared, or the cache is empty.
    ///
    /// The result is memoized per programming generation: a repeated hit
    /// on unchanged weights returns the stored output (reads are
    /// deterministic at a fixed generation — read noise keys off the
    /// generation tag), while any reprogramming in between — a training
    /// step's [`MemCore::program_delta`] included — invalidates the memo
    /// so the matmul re-runs against the new bits, never serving a stale
    /// output.
    pub fn matmul_from_cache(&mut self) -> Option<Matrix> {
        let hw = self.hw.as_ref()?;
        let prep = self.prepared.as_ref()?;
        let (_, ai) = self.input_cache.as_ref()?;
        if let Some((gen, y)) = &self.output_memo {
            if *gen == self.generation {
                return Some(y.clone());
            }
        }
        let y = hw.engine.matmul_prepared_inputs(ai, prep, self.generation);
        self.output_memo = Some((self.generation, y.clone()));
        Some(y)
    }

    /// Micro-batched hardware matmul (the [`crate::arch::MappedModel`]
    /// executor): the input is sliced **once for the full batch** (batch-
    /// global quantization scales), then row chunks of `micro_batch`
    /// samples (`rows_per_sample` matrix rows each) run on the `par_map`
    /// pool with engine-internal parallelism off. Bit-identical to
    /// [`MemCore::matmul_eval`] for every micro-batch size and thread
    /// count under the fixed-range ADC (see `arch::mapped` docs).
    pub fn matmul_batched(
        &self,
        x: &Matrix,
        micro_batch: usize,
        rows_per_sample: usize,
    ) -> Option<Matrix> {
        let hw = self.hw.as_ref()?;
        let prep = self.prepared.as_ref()?;
        let rps = rows_per_sample.max(1);
        let chunk_rows = micro_batch.max(1).saturating_mul(rps);
        if x.rows <= chunk_rows {
            return Some(hw.engine.matmul_prepared(x, prep, &hw.input_method, self.generation));
        }
        let ai = hw.engine.prepare_inputs(x, &hw.input_method);
        let n_chunks = x.rows.div_ceil(chunk_rows);
        let gen = self.generation;
        let outs: Vec<Matrix> = par_map(n_chunks, |ci| {
            let r0 = ci * chunk_rows;
            let len = chunk_rows.min(x.rows - r0);
            hw.engine.matmul_prepared_inputs_with(&ai.rows(r0, len), prep, gen, false)
        });
        let n = prep.shape().1;
        let mut out = Matrix::zeros(x.rows, n);
        let mut r = 0usize;
        for o in &outs {
            out.data[r * n..(r + o.rows) * n].copy_from_slice(&o.data);
            r += o.rows;
        }
        Some(out)
    }
}

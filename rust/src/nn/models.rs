//! Model zoo: LeNet-5 (Fig 16), MLP, and CIFAR-scale ResNet-18 / VGG-16
//! (Fig 17), each constructible fully digital, fully hardware, or mixed
//! (per-layer `HwSpec`s — Fig 9).
//!
//! The CIFAR models keep the papers' topologies (18-layer residual net with
//! [2,2,2,2] stages; VGG-16's 13 conv + 3 fc) but take a width parameter —
//! the offline testbed substitutes narrower nets trained on synthetic data
//! (see DESIGN.md §Substitutions); `width = 64` recovers the standard
//! configuration.

use super::layers::{
    AvgPool2, BatchNorm2d, Conv2dMem, Flatten, GlobalAvgPool, LinearMem, MaxPool2, Relu,
};
use super::{HwSpec, Layer, MemCore, Param, Sequential};
use crate::tensor::Tensor;
use crate::util::rng::Pcg64;

/// Basic residual block (two 3×3 convs + identity/projection skip).
pub struct ResidualBlock {
    conv1: Conv2dMem,
    bn1: BatchNorm2d,
    relu1: Relu,
    conv2: Conv2dMem,
    bn2: BatchNorm2d,
    proj: Option<(Conv2dMem, BatchNorm2d)>,
    relu_out: Relu,
    cache_x: Option<Tensor>,
}

impl ResidualBlock {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        in_c: usize,
        in_h: usize,
        in_w: usize,
        out_c: usize,
        stride: usize,
        hw: Option<HwSpec>,
        rng: &mut Pcg64,
    ) -> Self {
        let (oh, ow) = ((in_h - 1) / stride + 1, (in_w - 1) / stride + 1);
        let conv1 = Conv2dMem::new(in_c, in_h, in_w, out_c, 3, stride, 1, hw.clone(), rng);
        let conv2 = Conv2dMem::new(out_c, oh, ow, out_c, 3, 1, 1, hw.clone(), rng);
        let proj = if stride != 1 || in_c != out_c {
            Some((
                Conv2dMem::new(in_c, in_h, in_w, out_c, 1, stride, 0, hw, rng),
                BatchNorm2d::new(out_c),
            ))
        } else {
            None
        };
        ResidualBlock {
            conv1,
            bn1: BatchNorm2d::new(out_c),
            relu1: Relu::new(),
            conv2,
            bn2: BatchNorm2d::new(out_c),
            proj,
            relu_out: Relu::new(),
            cache_x: None,
        }
    }
}

impl Layer for ResidualBlock {
    fn forward(&mut self, x: &Tensor, train: bool) -> Tensor {
        let mut h = self.conv1.forward(x, train);
        h = self.bn1.forward(&h, train);
        h = self.relu1.forward(&h, train);
        h = self.conv2.forward(&h, train);
        h = self.bn2.forward(&h, train);
        let skip = match &mut self.proj {
            Some((conv, bn)) => {
                let s = conv.forward(x, train);
                bn.forward(&s, train)
            }
            None => x.clone(),
        };
        let mut sum = h;
        for (a, b) in sum.data.iter_mut().zip(&skip.data) {
            *a += b;
        }
        if train {
            self.cache_x = Some(x.clone());
        }
        self.relu_out.forward(&sum, train)
    }

    fn forward_eval(&self, x: &Tensor) -> Tensor {
        self.forward_batched(x, usize::MAX)
    }

    fn forward_batched(&self, x: &Tensor, micro_batch: usize) -> Tensor {
        // Same op order as `forward(x, false)`: conv/bn/relu main path,
        // projection (or identity) skip, sum, output relu. The DPE convs
        // take the micro-batch split; the digital layers are sample-wise.
        let mut h = self.conv1.forward_batched(x, micro_batch);
        h = self.bn1.forward_eval(&h);
        h = self.relu1.forward_eval(&h);
        h = self.conv2.forward_batched(&h, micro_batch);
        h = self.bn2.forward_eval(&h);
        let skip = match &self.proj {
            Some((conv, bn)) => bn.forward_eval(&conv.forward_batched(x, micro_batch)),
            None => x.clone(),
        };
        let mut sum = h;
        for (a, b) in sum.data.iter_mut().zip(&skip.data) {
            *a += b;
        }
        self.relu_out.forward_eval(&sum)
    }

    fn backward(&mut self, grad_out: &Tensor) -> Tensor {
        let _ = self.cache_x.take();
        let g_sum = self.relu_out.backward(grad_out);
        // Main path.
        let mut g = self.bn2.backward(&g_sum);
        g = self.conv2.backward(&g);
        g = self.relu1.backward(&g);
        g = self.bn1.backward(&g);
        let g_main = self.conv1.backward(&g);
        // Skip path.
        let g_skip = match &mut self.proj {
            Some((conv, bn)) => {
                let g = bn.backward(&g_sum);
                conv.backward(&g)
            }
            None => g_sum,
        };
        let mut out = g_main;
        for (a, b) in out.data.iter_mut().zip(&g_skip.data) {
            *a += b;
        }
        out
    }

    fn visit_params(&mut self, f: &mut dyn FnMut(&mut Param)) {
        self.conv1.visit_params(f);
        self.bn1.visit_params(f);
        self.conv2.visit_params(f);
        self.bn2.visit_params(f);
        if let Some((conv, bn)) = &mut self.proj {
            conv.visit_params(f);
            bn.visit_params(f);
        }
    }

    fn for_each_param(&self, f: &mut dyn FnMut(&Param)) {
        self.conv1.for_each_param(f);
        self.bn1.for_each_param(f);
        self.conv2.for_each_param(f);
        self.bn2.for_each_param(f);
        if let Some((conv, bn)) = &self.proj {
            conv.for_each_param(f);
            bn.for_each_param(f);
        }
    }

    fn visit_buffers(&mut self, f: &mut dyn FnMut(&mut Vec<f64>)) {
        self.bn1.visit_buffers(f);
        self.bn2.visit_buffers(f);
        if let Some((_, bn)) = &mut self.proj {
            bn.visit_buffers(f);
        }
    }

    fn for_each_buffer(&self, f: &mut dyn FnMut(&Vec<f64>)) {
        self.bn1.for_each_buffer(f);
        self.bn2.for_each_buffer(f);
        if let Some((_, bn)) = &self.proj {
            bn.for_each_buffer(f);
        }
    }

    fn update_weight(&mut self) {
        self.conv1.update_weight();
        self.conv2.update_weight();
        if let Some((conv, _)) = &mut self.proj {
            conv.update_weight();
        }
    }

    fn reprogram(&mut self) {
        self.conv1.reprogram();
        self.conv2.reprogram();
        if let Some((conv, _)) = &mut self.proj {
            conv.reprogram();
        }
    }

    fn visit_cores(&mut self, f: &mut dyn FnMut(&mut MemCore)) {
        self.conv1.visit_cores(f);
        self.conv2.visit_cores(f);
        if let Some((conv, _)) = &mut self.proj {
            conv.visit_cores(f);
        }
    }

    fn cores(&self) -> Vec<&MemCore> {
        let mut cs = self.conv1.cores();
        cs.extend(self.conv2.cores());
        if let Some((conv, _)) = &self.proj {
            cs.extend(conv.cores());
        }
        cs
    }

    fn name(&self) -> &'static str {
        "ResidualBlock"
    }

    fn out_shape(&self, in_shape: &[usize]) -> Vec<usize> {
        self.conv1.out_shape(in_shape)
    }
}

/// LeNet-5 for 28×28 grayscale (Fig 16): conv(1→6,5) – pool – conv(6→16,5)
/// – pool – fc 256→120→84→10 (matches `python/compile/model.py::lenet_fwd`).
pub fn lenet5(hw: Option<HwSpec>, seed: u64) -> Sequential {
    let mut rng = Pcg64::new(seed, 0x1E5E7);
    Sequential::new(vec![
        Box::new(Conv2dMem::new(1, 28, 28, 6, 5, 1, 0, hw.clone(), &mut rng)),
        Box::new(Relu::new()),
        Box::new(AvgPool2::new()),
        Box::new(Conv2dMem::new(6, 12, 12, 16, 5, 1, 0, hw.clone(), &mut rng)),
        Box::new(Relu::new()),
        Box::new(AvgPool2::new()),
        Box::new(Flatten::new()),
        Box::new(LinearMem::new(256, 120, hw.clone(), &mut rng)),
        Box::new(Relu::new()),
        Box::new(LinearMem::new(120, 84, hw.clone(), &mut rng)),
        Box::new(Relu::new()),
        Box::new(LinearMem::new(84, 10, hw, &mut rng)),
    ])
}

/// Two-layer MLP (quickstart / ablations).
pub fn mlp(input: usize, hidden: usize, classes: usize, hw: Option<HwSpec>, seed: u64) -> Sequential {
    let mut rng = Pcg64::new(seed, 0x3319);
    Sequential::new(vec![
        Box::new(Flatten::new()),
        Box::new(LinearMem::new(input, hidden, hw.clone(), &mut rng)),
        Box::new(Relu::new()),
        Box::new(LinearMem::new(hidden, classes, hw, &mut rng)),
    ])
}

/// ResNet-18 topology at CIFAR scale: stem conv3×3, stages [2,2,2,2] with
/// widths (w, 2w, 4w, 8w), global average pool, fc.
pub fn resnet18_cifar(width: usize, hw: Option<HwSpec>, seed: u64) -> Sequential {
    let mut rng = Pcg64::new(seed, 0x4E57);
    let w = width;
    let mut layers: Vec<Box<dyn Layer>> = vec![
        Box::new(Conv2dMem::new(3, 32, 32, w, 3, 1, 1, hw.clone(), &mut rng)),
        Box::new(BatchNorm2d::new(w)),
        Box::new(Relu::new()),
    ];
    let stages: [(usize, usize, usize, usize); 4] = [
        // (in_c, out_c, stride, spatial_in)
        (w, w, 1, 32),
        (w, 2 * w, 2, 32),
        (2 * w, 4 * w, 2, 16),
        (4 * w, 8 * w, 2, 8),
    ];
    for &(in_c, out_c, stride, hw_in) in &stages {
        layers.push(Box::new(ResidualBlock::new(
            in_c, hw_in, hw_in, out_c, stride, hw.clone(), &mut rng,
        )));
        let hw_out = (hw_in - 1) / stride + 1;
        layers.push(Box::new(ResidualBlock::new(
            out_c, hw_out, hw_out, out_c, 1, hw.clone(), &mut rng,
        )));
    }
    layers.push(Box::new(GlobalAvgPool::new()));
    layers.push(Box::new(LinearMem::new(8 * w, 10, hw, &mut rng)));
    Sequential::new(layers)
}

/// VGG-16 topology at CIFAR scale: 13 convs in 5 max-pooled groups with
/// widths (w, 2w, 4w, 8w, 8w), then fc ×3.
pub fn vgg16_cifar(width: usize, hw: Option<HwSpec>, seed: u64) -> Sequential {
    let mut rng = Pcg64::new(seed, 0x5657);
    let w = width;
    let groups: [(usize, usize); 5] = [(2, w), (2, 2 * w), (3, 4 * w), (3, 8 * w), (3, 8 * w)];
    let mut layers: Vec<Box<dyn Layer>> = Vec::new();
    let mut in_c = 3;
    let mut spatial = 32;
    for &(convs, out_c) in &groups {
        for _ in 0..convs {
            layers.push(Box::new(Conv2dMem::new(
                in_c, spatial, spatial, out_c, 3, 1, 1, hw.clone(), &mut rng,
            )));
            layers.push(Box::new(BatchNorm2d::new(out_c)));
            layers.push(Box::new(Relu::new()));
            in_c = out_c;
        }
        layers.push(Box::new(MaxPool2::new()));
        spatial /= 2;
    }
    // spatial is now 1: flatten 8w features.
    layers.push(Box::new(Flatten::new()));
    layers.push(Box::new(LinearMem::new(8 * w, 4 * w, hw.clone(), &mut rng)));
    layers.push(Box::new(Relu::new()));
    layers.push(Box::new(LinearMem::new(4 * w, 4 * w, hw.clone(), &mut rng)));
    layers.push(Box::new(Relu::new()));
    layers.push(Box::new(LinearMem::new(4 * w, 10, hw, &mut rng)));
    Sequential::new(layers)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dpe::{DotProductEngine, SliceMethod, SliceSpec};

    #[test]
    fn lenet_shapes() {
        let mut m = lenet5(None, 1);
        let x = Tensor::zeros(&[2, 1, 28, 28]);
        let y = m.forward(&x, false);
        assert_eq!(y.shape, vec![2, 10]);
        // 6·25+6 + 16·150+16 + 256·120+120 + 120·84+84 + 84·10+10
        assert_eq!(m.num_params(), 156 + 2416 + 30840 + 10164 + 850);
    }

    #[test]
    fn lenet_hw_forward_close_to_digital() {
        let hw = HwSpec::uniform(
            DotProductEngine::ideal((64, 64)),
            SliceMethod::int(SliceSpec::fp32()),
        );
        let mut m_hw = lenet5(Some(hw), 7);
        let mut m_dig = lenet5(None, 7);
        let x = Tensor::from_vec(
            &[2, 1, 28, 28],
            (0..2 * 784).map(|i| ((i * 37 % 101) as f64) / 101.0).collect(),
        );
        let y_hw = m_hw.forward(&x, false).to_matrix();
        let y_dig = m_dig.forward(&x, false).to_matrix();
        let re = y_hw.relative_error(&y_dig);
        assert!(re < 0.01, "re={re}");
    }

    #[test]
    fn resnet_shapes_and_backward() {
        let mut m = resnet18_cifar(4, None, 2);
        let x = Tensor::from_vec(
            &[2, 3, 32, 32],
            (0..2 * 3 * 1024).map(|i| ((i % 11) as f64) / 11.0).collect(),
        );
        let y = m.forward(&x, true);
        assert_eq!(y.shape, vec![2, 10]);
        let g = m.backward(&y);
        assert_eq!(g.shape, x.shape);
        assert!(g.data.iter().any(|&v| v != 0.0));
    }

    #[test]
    fn vgg_shapes_and_backward() {
        let mut m = vgg16_cifar(2, None, 3);
        let x = Tensor::from_vec(
            &[1, 3, 32, 32],
            (0..3 * 1024).map(|i| ((i % 13) as f64) / 13.0).collect(),
        );
        let y = m.forward(&x, true);
        assert_eq!(y.shape, vec![1, 10]);
        let g = m.backward(&y);
        assert_eq!(g.shape, x.shape);
    }

    #[test]
    fn residual_block_gradcheck() {
        let mut rng = Pcg64::seeded(5);
        let mut blk = ResidualBlock::new(2, 4, 4, 3, 2, None, &mut rng);
        let x = Tensor::from_vec(&[1, 2, 4, 4], (0..32).map(|i| (i as f64) / 16.0 - 1.0).collect());
        let y = blk.forward(&x, true);
        let gx = blk.backward(&y);
        // Numerical check on a few coordinates. BatchNorm uses batch stats,
        // forward(train=true) keeps semantics identical.
        for idx in [0usize, 13, 31] {
            let eps = 1e-5;
            let mut xp = x.clone();
            xp.data[idx] += eps;
            let mut xm = x.clone();
            xm.data[idx] -= eps;
            let lp: f64 = blk.forward(&xp, true).data.iter().map(|v| v * v).sum::<f64>() / 2.0;
            let lm: f64 = blk.forward(&xm, true).data.iter().map(|v| v * v).sum::<f64>() / 2.0;
            let want = (lp - lm) / (2.0 * eps);
            assert!(
                (gx.data[idx] - want).abs() < 2e-4,
                "idx {idx}: {} vs {want}",
                gx.data[idx]
            );
        }
    }

    #[test]
    fn mixed_precision_layers_supported() {
        // Fig 9: different engines/methods per layer in one model.
        let mut rng = Pcg64::new(9, 9);
        let hw8 = HwSpec::uniform(
            DotProductEngine::ideal((64, 64)),
            SliceMethod::int(SliceSpec::int8()),
        );
        let hw4 = HwSpec::uniform(
            DotProductEngine::ideal((32, 32)),
            SliceMethod::int(SliceSpec::int4()),
        );
        let mut m = Sequential::new(vec![
            Box::new(Flatten::new()),
            Box::new(LinearMem::new(16, 12, Some(hw8), &mut rng)),
            Box::new(Relu::new()),
            Box::new(LinearMem::new(12, 4, Some(hw4), &mut rng)),
        ]);
        let x = Tensor::from_vec(&[2, 16], (0..32).map(|i| (i as f64) / 32.0).collect());
        let y = m.forward(&x, false);
        assert_eq!(y.shape, vec![2, 4]);
    }
}

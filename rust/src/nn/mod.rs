//! Hardware neural-network layers with a computing graph (paper §3.4).
//!
//! The paper builds PyTorch layers whose **forward pass runs on the
//! hardware DPE** (quantized, sliced, noisy) while the **backward pass
//! applies errors to the full-precision weights and inputs** ("to ensure
//! the model is trainable and not trapped in the local minimum") — the
//! straight-through scheme. This module reproduces that design natively:
//!
//! - [`Layer`] — forward/backward/param plumbing (explicit backprop;
//!   activations cached per layer exactly like autograd saved tensors),
//!   plus the immutable eval entry points (`forward_eval` /
//!   `forward_batched`) the mapped inference executor uses;
//! - [`layers`] — `LinearMem`, `Conv2dMem` (im2col), pooling, ReLU,
//!   `BatchNorm2d` (digital), flatten;
//! - [`core`] — [`MemCore`], the shared hardware state (engine binding,
//!   programmed weights, physical-slot streams, input cache) every
//!   DPE-backed layer embeds;
//! - [`HwSpec`] — per-layer hardware binding: each layer owns its engine
//!   configuration and slice methods (ultra-flexible layer-wise
//!   mixed-precision, Fig 9(a)), or `None` for a full-precision digital
//!   layer (hybrid structures, Fig 9(b));
//! - [`models`] — LeNet-5, MLP, CIFAR-scale ResNet-18 and VGG-16;
//! - [`optim`] / [`loss`] / [`train`] — SGD/Adam, softmax cross-entropy,
//!   and the training/eval loops.
//!
//! Weights are kept in full precision; `update_weight()` refreshes the
//! sliced+programmed hardware copy (the paper's `update_weight()`), which
//! layers reuse across forward passes until the next optimizer step.
//!
//! # Chip mapping
//!
//! Every hardware core draws its programming noise, fault masks, and ADC
//! chains from the RNG streams of the **physical arrays** its weight
//! blocks occupy (see [`crate::arch`]). A [`Sequential`] assigns those
//! slots at construction from a *virtual* layer-order packing — so two
//! co-located layers never share streams — and
//! [`Sequential::compile`] re-places them on a concrete
//! [`crate::arch::ChipSpec`], programs the whole chip once, and returns a
//! forward-only [`crate::arch::MappedModel`] with micro-batched inference.
//! A single-tile chip large enough for the whole model reproduces the
//! virtual packing and is therefore bit-identical to the unmapped path.

pub mod core;
pub mod layers;
pub mod loss;
pub mod models;
pub mod optim;
pub mod train;

pub use self::core::MemCore;

use crate::arch::{ChipSpec, CoreDemand, MappedModel, TileAllocator};
use crate::dpe::{DeltaReport, DotProductEngine, SliceMethod};
use crate::tensor::Tensor;
use std::sync::Arc;

/// Typed failure of a training-graph operation — the structured
/// alternative to the old `expect("forward(train=true) before backward")`
/// panics in the hardware layers.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TrainError {
    /// `backward` was called on a layer whose activation cache is empty:
    /// either no `forward(x, train=true)` preceded it, or the cache was
    /// already consumed by a previous `backward` (double-backward).
    BackwardBeforeForward {
        /// `Layer::name()` of the offending layer.
        layer: &'static str,
    },
}

impl std::fmt::Display for TrainError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TrainError::BackwardBeforeForward { layer } => write!(
                f,
                "{layer}: backward without a cached activation — call forward(x, train=true) \
                 before each backward (the cache is consumed per backward, so this is also \
                 what a double-backward hits)"
            ),
        }
    }
}

impl std::error::Error for TrainError {}

/// Per-layer hardware binding: the engine plus input/weight slice methods
/// (the paper's `input_sli_med` / `weight_sli_med` constructor arguments).
#[derive(Debug, Clone)]
pub struct HwSpec {
    pub engine: Arc<DotProductEngine>,
    pub input_method: SliceMethod,
    pub weight_method: SliceMethod,
}

impl HwSpec {
    pub fn new(
        engine: DotProductEngine,
        input_method: SliceMethod,
        weight_method: SliceMethod,
    ) -> Self {
        HwSpec { engine: Arc::new(engine), input_method, weight_method }
    }

    /// Same slice method on both operands (the common configuration in §5).
    pub fn uniform(engine: DotProductEngine, method: SliceMethod) -> Self {
        HwSpec { engine: Arc::new(engine), input_method: method.clone(), weight_method: method }
    }
}

/// A parameter tensor with its gradient accumulator.
pub struct Param {
    pub value: Vec<f64>,
    pub grad: Vec<f64>,
}

impl Param {
    pub fn new(value: Vec<f64>) -> Self {
        let grad = vec![0.0; value.len()];
        Param { value, grad }
    }

    pub fn zero_grad(&mut self) {
        self.grad.fill(0.0);
    }
}

/// A differentiable layer. `forward` caches whatever `backward` needs;
/// `backward` consumes the cache, accumulates parameter gradients, and
/// returns the input gradient. `forward_eval` is the immutable inference
/// path (no caches touched) used by the mapped executor — it must be
/// bit-identical to `forward(x, false)` absent the opt-in input cache.
///
/// `Send + Sync` so boxed layers can be shared across the inference
/// worker pool ([`crate::arch::MappedModel::infer_batched`]).
pub trait Layer: Send + Sync {
    fn forward(&mut self, x: &Tensor, train: bool) -> Tensor;
    fn backward(&mut self, grad_out: &Tensor) -> Tensor;
    /// Fallible backward: layers that need a cached activation return
    /// [`TrainError::BackwardBeforeForward`] instead of panicking when it
    /// is missing (backward-before-forward, double-backward). Layers
    /// overriding this put the real logic here and delegate `backward` to
    /// it; the default wraps the panicking `backward` for digital layers
    /// whose caches are cheap shape records.
    fn try_backward(&mut self, grad_out: &Tensor) -> Result<Tensor, TrainError> {
        Ok(self.backward(grad_out))
    }
    /// Immutable eval-mode forward (inference executor path).
    fn forward_eval(&self, x: &Tensor) -> Tensor;
    /// Eval forward over a batch, splitting DPE work into micro-batches of
    /// `micro_batch` samples. Sample-wise digital layers just evaluate the
    /// whole batch.
    fn forward_batched(&self, x: &Tensor, micro_batch: usize) -> Tensor {
        let _ = micro_batch;
        self.forward_eval(x)
    }
    /// Visit parameters mutably (for the optimizer).
    fn visit_params(&mut self, f: &mut dyn FnMut(&mut Param)) {
        let _ = f;
    }
    /// Visit parameters read-only (state export — e.g. the donor side of
    /// [`Sequential::load_state_from`]). Must mirror `visit_params`' order.
    fn for_each_param(&self, f: &mut dyn FnMut(&Param)) {
        let _ = f;
    }
    /// Visit non-parameter state buffers (e.g. BatchNorm running stats),
    /// needed when transferring a trained model between engine bindings.
    fn visit_buffers(&mut self, f: &mut dyn FnMut(&mut Vec<f64>)) {
        let _ = f;
    }
    /// Read-only buffer visitor mirroring `visit_buffers`' order.
    fn for_each_buffer(&self, f: &mut dyn FnMut(&Vec<f64>)) {
        let _ = f;
    }
    /// Refresh the hardware (sliced/programmed) weight copy from the
    /// full-precision weights — the paper's `update_weight()`.
    fn update_weight(&mut self) {}
    /// Delta variant of [`Layer::update_weight`] for the training hot loop
    /// (`dpe::engine` §Perf training path): hardware layers route through
    /// [`MemCore::program_delta`] so only blocks whose quantized digits
    /// changed are touched, and report what was redrawn. The default (for
    /// digital layers, whose `update_weight` is a no-op) performs a plain
    /// `update_weight` and reports zero work.
    fn update_weight_delta(&mut self) -> DeltaReport {
        self.update_weight();
        DeltaReport::default()
    }
    /// Re-derive the hardware copies at the **current** programming
    /// generation — called after the layer's cores were moved to different
    /// physical slots (their RNG streams changed, the weights did not).
    fn reprogram(&mut self) {}
    /// Visit the layer's hardware cores mutably (slot assignment). Digital
    /// layers have none.
    fn visit_cores(&mut self, f: &mut dyn FnMut(&mut MemCore)) {
        let _ = f;
    }
    /// Read-only view of the layer's hardware cores (demand collection,
    /// summaries).
    fn cores(&self) -> Vec<&MemCore> {
        Vec::new()
    }
    fn name(&self) -> &'static str;
    /// Output shape for a given input shape (sanity checks / model summary).
    fn out_shape(&self, in_shape: &[usize]) -> Vec<usize>;
}

/// A sequential model.
pub struct Sequential {
    pub layers: Vec<Box<dyn Layer>>,
}

impl Sequential {
    /// Build the model and assign every hardware core its physical-stream
    /// slots from the virtual layer-order packing (one unbounded tile):
    /// co-located layers draw from disjoint per-array RNG streams, and a
    /// later [`Sequential::compile`] onto a single sufficient tile
    /// reproduces these streams exactly (the bit-identity anchor).
    pub fn new(layers: Vec<Box<dyn Layer>>) -> Self {
        let mut s = Sequential { layers };
        s.assign_virtual_slots();
        s
    }

    fn assign_virtual_slots(&mut self) {
        let mut next = 0u64;
        for l in self.layers.iter_mut() {
            let mut changed = false;
            l.visit_cores(&mut |c| {
                if let Some((blocks, slices)) = c.demand() {
                    changed |= c.set_contiguous_base(next);
                    next += (blocks * slices) as u64;
                }
            });
            if changed {
                l.reprogram();
            }
        }
    }

    /// Total physical arrays the model's hardware cores demand (digit
    /// planes across all weight blocks) — the chip capacity needed to map
    /// it.
    pub fn mapped_planes(&self) -> usize {
        self.layers
            .iter()
            .flat_map(|l| l.cores())
            .filter_map(|c| c.arrays_used())
            .sum()
    }

    /// A chip guaranteed to fit this model: tiles of `arrays_per_tile`
    /// arrays (grown to the largest block group if needed), with enough
    /// tiles to absorb group-spill fragmentation — a tile only spills when
    /// the incoming group does not fit, so every spilled-past tile holds at
    /// least `arrays_per_tile − (max_group − 1)` planes.
    pub fn auto_chip(&self, arrays_per_tile: usize, array: (usize, usize)) -> ChipSpec {
        let total = self.mapped_planes();
        let s_max = self
            .layers
            .iter()
            .flat_map(|l| l.cores())
            .filter_map(|c| c.demand())
            .map(|(_, slices)| slices)
            .max()
            .unwrap_or(1);
        let apt = arrays_per_tile.max(s_max).max(1);
        let effective = apt - (s_max - 1);
        ChipSpec::new(total.div_ceil(effective).max(1), apt, array)
    }

    /// Compile the model onto a chip: bin-pack every hardware core's
    /// weight block grid onto physical tiles ([`TileAllocator`]), key each
    /// block's programming streams to its slots, program the whole chip
    /// once (at the current generation — the weights are unchanged), and
    /// return the forward-only [`MappedModel`] runtime.
    ///
    /// Errors when an engine's array shape differs from the chip's or the
    /// chip is too small (capacity report attached).
    pub fn compile(mut self, chip: &ChipSpec) -> anyhow::Result<MappedModel> {
        // 1. Collect demands in model order (the same traversal assigns
        //    the placements below).
        let mut demands: Vec<CoreDemand> = Vec::new();
        let mut mismatch: Option<String> = None;
        for (li, l) in self.layers.iter_mut().enumerate() {
            let name = l.name();
            l.visit_cores(&mut |c| {
                if let Some((blocks, slices)) = c.demand() {
                    if let Some(hw) = c.hw() {
                        if hw.engine.cfg.array != chip.array && mismatch.is_none() {
                            mismatch = Some(format!(
                                "layer {li} ({name}) engine array {:?} != chip array {:?}",
                                hw.engine.cfg.array, chip.array
                            ));
                        }
                    }
                    demands.push(CoreDemand { layer: li, name, blocks, slices });
                }
            });
        }
        if let Some(m) = mismatch {
            anyhow::bail!("cannot map model onto chip: {m}");
        }
        let placement = TileAllocator::allocate(chip, &demands)?;

        // 2. Adopt the slot streams and program the whole chip once.
        //    Cores whose effective streams are unchanged (the single-tile
        //    layer-order anchor reproduces the virtual packing exactly)
        //    already hold the right bits and are not re-programmed.
        {
            let mut next_core = 0usize;
            let placed = &placement.layers;
            for l in self.layers.iter_mut() {
                let mut any_changed = false;
                l.visit_cores(&mut |c| {
                    if c.demand().is_some() {
                        any_changed |= c.set_block_streams(placed[next_core].clone());
                        next_core += 1;
                    }
                });
                if any_changed {
                    l.reprogram();
                }
            }
            assert_eq!(next_core, placed.len(), "placement/core count mismatch");
        }
        Ok(MappedModel::new(self, placement))
    }

    /// Compile the model across an ordered fleet of chips (multi-chip
    /// sharding, [`crate::arch::fleet`]): contiguous layer runs become
    /// pipeline stages, one per chip, with a single oversized layer
    /// block-split across several homogeneous chips; leftover chips form
    /// the failover spare pool. See [`crate::arch::ShardedModel`] for
    /// the bit-identity and fault-tolerance contracts.
    pub fn compile_sharded(
        self,
        fleet: &[ChipSpec],
    ) -> anyhow::Result<crate::arch::ShardedModel> {
        crate::arch::ShardedModel::compile(self, fleet)
    }

    pub fn forward(&mut self, x: &Tensor, train: bool) -> Tensor {
        let mut h = x.clone();
        for l in self.layers.iter_mut() {
            h = l.forward(&h, train);
        }
        h
    }

    pub fn backward(&mut self, grad: &Tensor) -> Tensor {
        let mut g = grad.clone();
        for l in self.layers.iter_mut().rev() {
            g = l.backward(&g);
        }
        g
    }

    /// Fallible backward ([`Layer::try_backward`]): the first layer with a
    /// missing activation cache aborts the pass with a typed error
    /// identifying it, instead of panicking mid-stack.
    pub fn try_backward(&mut self, grad: &Tensor) -> Result<Tensor, TrainError> {
        let mut g = grad.clone();
        for l in self.layers.iter_mut().rev() {
            g = l.try_backward(&g)?;
        }
        Ok(g)
    }

    pub fn visit_params(&mut self, f: &mut dyn FnMut(&mut Param)) {
        for l in self.layers.iter_mut() {
            l.visit_params(f);
        }
    }

    /// Read-only parameter traversal (same order as `visit_params`).
    pub fn for_each_param(&self, f: &mut dyn FnMut(&Param)) {
        for l in &self.layers {
            l.for_each_param(f);
        }
    }

    pub fn visit_buffers(&mut self, f: &mut dyn FnMut(&mut Vec<f64>)) {
        for l in self.layers.iter_mut() {
            l.visit_buffers(f);
        }
    }

    /// Read-only buffer traversal (same order as `visit_buffers`).
    pub fn for_each_buffer(&self, f: &mut dyn FnMut(&Vec<f64>)) {
        for l in &self.layers {
            l.for_each_buffer(f);
        }
    }

    /// Copy all parameters and buffers from another model with identical
    /// topology (the paper's `load_state_dict` flow); call
    /// `update_weight()` afterwards to program the arrays. The donor is
    /// only read — loading state cannot perturb it.
    pub fn load_state_from(&mut self, src: &Sequential) {
        let mut params: Vec<Vec<f64>> = Vec::new();
        src.for_each_param(&mut |p| params.push(p.value.clone()));
        let mut i = 0;
        self.visit_params(&mut |p| {
            assert_eq!(p.value.len(), params[i].len(), "param shape mismatch");
            p.value.copy_from_slice(&params[i]);
            i += 1;
        });
        assert_eq!(i, params.len(), "param count mismatch");
        let mut bufs: Vec<Vec<f64>> = Vec::new();
        src.for_each_buffer(&mut |b| bufs.push(b.clone()));
        let mut j = 0;
        self.visit_buffers(&mut |b| {
            b.copy_from_slice(&bufs[j]);
            j += 1;
        });
        assert_eq!(j, bufs.len(), "buffer count mismatch");
    }

    pub fn update_weight(&mut self) {
        for l in self.layers.iter_mut() {
            l.update_weight();
        }
    }

    /// Delta-reprogram every hardware layer after an optimizer step
    /// ([`Layer::update_weight_delta`]), summing the per-layer redraw
    /// accounting — the training hot loop's replacement for
    /// [`Sequential::update_weight`].
    pub fn update_weight_delta(&mut self) -> DeltaReport {
        let mut total = DeltaReport::default();
        for l in self.layers.iter_mut() {
            total.merge(&l.update_weight_delta());
        }
        total
    }

    pub fn zero_grad(&mut self) {
        self.visit_params(&mut |p| p.zero_grad());
    }

    pub fn num_params(&mut self) -> usize {
        let mut n = 0;
        self.visit_params(&mut |p| n += p.value.len());
        n
    }

    /// Model summary line per layer; hardware layers get an arrays column,
    /// and — once compiled onto a chip — their assigned tile range.
    pub fn summary(&self, mut in_shape: Vec<usize>) -> String {
        let mut s = String::new();
        for l in &self.layers {
            let out = l.out_shape(&in_shape);
            s.push_str(&format!("{:<12} {:?} -> {:?}", l.name(), in_shape, out));
            let cores = l.cores();
            let arrays: usize = cores.iter().filter_map(|c| c.arrays_used()).sum();
            if arrays > 0 {
                s.push_str(&format!("  arrays={arrays}"));
                let tiles: Vec<(usize, usize)> = cores
                    .iter()
                    .filter_map(|c| c.placement())
                    .map(|p| (p.tile_first, p.tile_last))
                    .collect();
                if let (Some(first), Some(last)) = (
                    tiles.iter().map(|t| t.0).min(),
                    tiles.iter().map(|t| t.1).max(),
                ) {
                    s.push_str(&format!(" tiles={first}..={last}"));
                }
                // Degraded mode: block groups fenced off by self_heal /
                // condemn serve exactly zero — surface that here so a
                // degraded chip is visible in every report, not only via
                // MappedModel::degraded().
                let condemned: usize =
                    cores.iter().map(|c| c.condemned_blocks().len()).sum();
                if condemned > 0 {
                    s.push_str(&format!(" condemned={condemned}"));
                }
            }
            s.push('\n');
            in_shape = out;
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::layers::{Flatten, LinearMem, Relu};
    use super::*;
    use crate::arch::ChipSpec;
    use crate::dpe::{DpeConfig, SliceSpec};
    use crate::util::rng::Pcg64;

    #[test]
    fn sequential_shapes_and_params() {
        let mut rng = Pcg64::seeded(1);
        let mut m = Sequential::new(vec![
            Box::new(Flatten::new()),
            Box::new(LinearMem::new(12, 5, None, &mut rng)),
            Box::new(Relu::new()),
            Box::new(LinearMem::new(5, 3, None, &mut rng)),
        ]);
        let x = Tensor::from_vec(&[2, 3, 4], vec![0.1; 24]);
        let y = m.forward(&x, true);
        assert_eq!(y.shape, vec![2, 3]);
        assert_eq!(m.num_params(), 12 * 5 + 5 + 5 * 3 + 3);
        let summary = m.summary(vec![2, 3, 4]);
        assert!(summary.contains("LinearMem"));
    }

    #[test]
    fn load_state_from_reads_donor_immutably() {
        let mut rng = Pcg64::seeded(2);
        let src = Sequential::new(vec![Box::new(LinearMem::new(6, 4, None, &mut rng))]);
        let mut dst = Sequential::new(vec![Box::new(LinearMem::new(6, 4, None, &mut rng))]);
        let mut before: Vec<Vec<f64>> = Vec::new();
        src.for_each_param(&mut |p| before.push(p.value.clone()));
        dst.load_state_from(&src);
        let mut after: Vec<Vec<f64>> = Vec::new();
        src.for_each_param(&mut |p| after.push(p.value.clone()));
        assert_eq!(before, after, "donor must be untouched");
        let mut dst_params: Vec<Vec<f64>> = Vec::new();
        dst.for_each_param(&mut |p| dst_params.push(p.value.clone()));
        assert_eq!(dst_params, before, "receiver must match donor");
    }

    #[test]
    fn auto_chip_absorbs_group_fragmentation() {
        // ones(3) groups in 4-slot tiles waste one slot per tile; a naive
        // exact-capacity chip would run out mid-allocation.
        let hw = HwSpec::uniform(
            DotProductEngine::new(DpeConfig::default(), 6),
            SliceMethod::parse("ones3").unwrap(),
        );
        let mut rng = Pcg64::seeded(6);
        let m = Sequential::new(vec![Box::new(LinearMem::new(80, 8, Some(hw), &mut rng))]);
        assert_eq!(m.mapped_planes(), 6); // 2 k-blocks x 1 n-block x 3 slices
        let chip = m.auto_chip(4, (64, 64));
        assert!(chip.tiles * chip.arrays_per_tile >= 8, "chip must include spill slack");
        m.compile(&chip).expect("auto-sized chip fits");
    }

    #[test]
    fn summary_shows_arrays_and_tiles_when_compiled() {
        let hw = HwSpec::uniform(
            DotProductEngine::new(DpeConfig::default(), 4),
            SliceMethod::int(SliceSpec::int8()),
        );
        let mut rng = Pcg64::seeded(4);
        let m = Sequential::new(vec![
            Box::new(Flatten::new()),
            Box::new(LinearMem::new(80, 8, Some(hw), &mut rng)),
        ]);
        let plain = m.summary(vec![1, 80]);
        assert!(plain.contains("arrays="), "{plain}");
        assert!(!plain.contains("tiles="), "{plain}");
        let planes = m.mapped_planes();
        assert_eq!(planes, 2 * 4); // 2 k-blocks x 1 n-block x 4 slices
        let mapped = m.compile(&ChipSpec::single_tile(planes, (64, 64))).unwrap();
        let s = mapped.summary(vec![1, 80]);
        assert!(s.contains("arrays=8"), "{s}");
        assert!(s.contains("tiles=0..=0"), "{s}");
    }
}

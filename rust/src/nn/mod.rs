//! Hardware neural-network layers with a computing graph (paper §3.4).
//!
//! The paper builds PyTorch layers whose **forward pass runs on the
//! hardware DPE** (quantized, sliced, noisy) while the **backward pass
//! applies errors to the full-precision weights and inputs** ("to ensure
//! the model is trainable and not trapped in the local minimum") — the
//! straight-through scheme. This module reproduces that design natively:
//!
//! - [`Layer`] — forward/backward/param plumbing (explicit backprop;
//!   activations cached per layer exactly like autograd saved tensors);
//! - [`layers`] — `LinearMem`, `Conv2dMem` (im2col), pooling, ReLU,
//!   `BatchNorm2d` (digital), flatten;
//! - [`HwSpec`] — per-layer hardware binding: each layer owns its engine
//!   configuration and slice methods (ultra-flexible layer-wise
//!   mixed-precision, Fig 9(a)), or `None` for a full-precision digital
//!   layer (hybrid structures, Fig 9(b));
//! - [`models`] — LeNet-5, MLP, CIFAR-scale ResNet-18 and VGG-16;
//! - [`optim`] / [`loss`] / [`train`] — SGD/Adam, softmax cross-entropy,
//!   and the training/eval loops.
//!
//! Weights are kept in full precision; `update_weight()` refreshes the
//! sliced+programmed hardware copy (the paper's `update_weight()`), which
//! layers reuse across forward passes until the next optimizer step.

pub mod layers;
pub mod loss;
pub mod models;
pub mod optim;
pub mod train;

use crate::dpe::{DotProductEngine, SliceMethod};
use crate::tensor::Tensor;
use std::sync::Arc;

/// Per-layer hardware binding: the engine plus input/weight slice methods
/// (the paper's `input_sli_med` / `weight_sli_med` constructor arguments).
#[derive(Debug, Clone)]
pub struct HwSpec {
    pub engine: Arc<DotProductEngine>,
    pub input_method: SliceMethod,
    pub weight_method: SliceMethod,
}

impl HwSpec {
    pub fn new(
        engine: DotProductEngine,
        input_method: SliceMethod,
        weight_method: SliceMethod,
    ) -> Self {
        HwSpec { engine: Arc::new(engine), input_method, weight_method }
    }

    /// Same slice method on both operands (the common configuration in §5).
    pub fn uniform(engine: DotProductEngine, method: SliceMethod) -> Self {
        HwSpec { engine: Arc::new(engine), input_method: method.clone(), weight_method: method }
    }
}

/// A parameter tensor with its gradient accumulator.
pub struct Param {
    pub value: Vec<f64>,
    pub grad: Vec<f64>,
}

impl Param {
    pub fn new(value: Vec<f64>) -> Self {
        let grad = vec![0.0; value.len()];
        Param { value, grad }
    }

    pub fn zero_grad(&mut self) {
        self.grad.fill(0.0);
    }
}

/// A differentiable layer. `forward` caches whatever `backward` needs;
/// `backward` consumes the cache, accumulates parameter gradients, and
/// returns the input gradient.
pub trait Layer {
    fn forward(&mut self, x: &Tensor, train: bool) -> Tensor;
    fn backward(&mut self, grad_out: &Tensor) -> Tensor;
    /// Visit parameters (for the optimizer).
    fn visit_params(&mut self, f: &mut dyn FnMut(&mut Param)) {
        let _ = f;
    }
    /// Visit non-parameter state buffers (e.g. BatchNorm running stats),
    /// needed when transferring a trained model between engine bindings.
    fn visit_buffers(&mut self, f: &mut dyn FnMut(&mut Vec<f64>)) {
        let _ = f;
    }
    /// Refresh the hardware (sliced/programmed) weight copy from the
    /// full-precision weights — the paper's `update_weight()`.
    fn update_weight(&mut self) {}
    fn name(&self) -> &'static str;
    /// Output shape for a given input shape (sanity checks / model summary).
    fn out_shape(&self, in_shape: &[usize]) -> Vec<usize>;
}

/// A sequential model.
pub struct Sequential {
    pub layers: Vec<Box<dyn Layer>>,
}

impl Sequential {
    pub fn new(layers: Vec<Box<dyn Layer>>) -> Self {
        Sequential { layers }
    }

    pub fn forward(&mut self, x: &Tensor, train: bool) -> Tensor {
        let mut h = x.clone();
        for l in self.layers.iter_mut() {
            h = l.forward(&h, train);
        }
        h
    }

    pub fn backward(&mut self, grad: &Tensor) -> Tensor {
        let mut g = grad.clone();
        for l in self.layers.iter_mut().rev() {
            g = l.backward(&g);
        }
        g
    }

    pub fn visit_params(&mut self, f: &mut dyn FnMut(&mut Param)) {
        for l in self.layers.iter_mut() {
            l.visit_params(f);
        }
    }

    pub fn visit_buffers(&mut self, f: &mut dyn FnMut(&mut Vec<f64>)) {
        for l in self.layers.iter_mut() {
            l.visit_buffers(f);
        }
    }

    /// Copy all parameters and buffers from another model with identical
    /// topology (the paper's `load_state_dict` flow); call
    /// `update_weight()` afterwards to program the arrays.
    pub fn load_state_from(&mut self, src: &mut Sequential) {
        let mut params: Vec<Vec<f64>> = Vec::new();
        src.visit_params(&mut |p| params.push(p.value.clone()));
        let mut i = 0;
        self.visit_params(&mut |p| {
            assert_eq!(p.value.len(), params[i].len(), "param shape mismatch");
            p.value.copy_from_slice(&params[i]);
            i += 1;
        });
        assert_eq!(i, params.len(), "param count mismatch");
        let mut bufs: Vec<Vec<f64>> = Vec::new();
        src.visit_buffers(&mut |b| bufs.push(b.clone()));
        let mut j = 0;
        self.visit_buffers(&mut |b| {
            b.copy_from_slice(&bufs[j]);
            j += 1;
        });
        assert_eq!(j, bufs.len(), "buffer count mismatch");
    }

    pub fn update_weight(&mut self) {
        for l in self.layers.iter_mut() {
            l.update_weight();
        }
    }

    pub fn zero_grad(&mut self) {
        self.visit_params(&mut |p| p.zero_grad());
    }

    pub fn num_params(&mut self) -> usize {
        let mut n = 0;
        self.visit_params(&mut |p| n += p.value.len());
        n
    }

    /// Model summary line per layer.
    pub fn summary(&self, mut in_shape: Vec<usize>) -> String {
        let mut s = String::new();
        for l in &self.layers {
            let out = l.out_shape(&in_shape);
            s.push_str(&format!("{:<12} {:?} -> {:?}\n", l.name(), in_shape, out));
            in_shape = out;
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::layers::{Flatten, LinearMem, Relu};
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn sequential_shapes_and_params() {
        let mut rng = Pcg64::seeded(1);
        let mut m = Sequential::new(vec![
            Box::new(Flatten::new()),
            Box::new(LinearMem::new(12, 5, None, &mut rng)),
            Box::new(Relu::new()),
            Box::new(LinearMem::new(5, 3, None, &mut rng)),
        ]);
        let x = Tensor::from_vec(&[2, 3, 4], vec![0.1; 24]);
        let y = m.forward(&x, true);
        assert_eq!(y.shape, vec![2, 3]);
        assert_eq!(m.num_params(), 12 * 5 + 5 + 5 * 3 + 3);
        let summary = m.summary(vec![2, 3, 4]);
        assert!(summary.contains("LinearMem"));
    }
}

//! Optimizers operating on the full-precision master weights (the
//! hardware copies are refreshed via `update_weight()` — or by
//! template delta via `update_weight_delta()` on the fast training
//! path — after each step). Momentum/moment buffers are sized lazily
//! on the first step and reused for the rest of training; the only
//! per-step allocation in the hot loop is the gradient math itself.

use super::Sequential;

/// SGD with momentum and optional weight decay.
pub struct Sgd {
    pub lr: f64,
    pub momentum: f64,
    pub weight_decay: f64,
    velocity: Vec<Vec<f64>>,
}

impl Sgd {
    pub fn new(lr: f64, momentum: f64, weight_decay: f64) -> Self {
        Sgd { lr, momentum, weight_decay, velocity: Vec::new() }
    }

    pub fn step(&mut self, model: &mut Sequential) {
        let mut idx = 0;
        // Lazily size the velocity buffers on first step.
        let need_init = self.velocity.is_empty();
        let velocity = &mut self.velocity;
        let (lr, mu, wd) = (self.lr, self.momentum, self.weight_decay);
        model.visit_params(&mut |p| {
            if need_init {
                velocity.push(vec![0.0; p.value.len()]);
            }
            let v = &mut velocity[idx];
            assert_eq!(v.len(), p.value.len(), "param set changed between steps");
            for ((value, grad), vel) in p.value.iter_mut().zip(&p.grad).zip(v.iter_mut()) {
                let g = grad + wd * *value;
                *vel = mu * *vel + g;
                *value -= lr * *vel;
            }
            idx += 1;
        });
    }
}

/// Adam.
pub struct Adam {
    pub lr: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    t: u64,
    m: Vec<Vec<f64>>,
    v: Vec<Vec<f64>>,
}

impl Adam {
    pub fn new(lr: f64) -> Self {
        Adam { lr, beta1: 0.9, beta2: 0.999, eps: 1e-8, t: 0, m: Vec::new(), v: Vec::new() }
    }

    pub fn step(&mut self, model: &mut Sequential) {
        self.t += 1;
        let mut idx = 0;
        let need_init = self.m.is_empty();
        let (m_all, v_all) = (&mut self.m, &mut self.v);
        let (lr, b1, b2, eps, t) = (self.lr, self.beta1, self.beta2, self.eps, self.t);
        let bc1 = 1.0 - b1.powi(t as i32);
        let bc2 = 1.0 - b2.powi(t as i32);
        model.visit_params(&mut |p| {
            if need_init {
                m_all.push(vec![0.0; p.value.len()]);
                v_all.push(vec![0.0; p.value.len()]);
            }
            let m = &mut m_all[idx];
            let v = &mut v_all[idx];
            for i in 0..p.value.len() {
                let g = p.grad[i];
                m[i] = b1 * m[i] + (1.0 - b1) * g;
                v[i] = b2 * v[i] + (1.0 - b2) * g * g;
                let mh = m[i] / bc1;
                let vh = v[i] / bc2;
                p.value[i] -= lr * mh / (vh.sqrt() + eps);
            }
            idx += 1;
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::layers::LinearMem;
    use crate::nn::Sequential;
    use crate::tensor::Tensor;
    use crate::util::rng::Pcg64;

    /// One linear layer fit to a fixed target with quadratic loss must
    /// reduce the loss monotonically-ish.
    fn fit(optim: &mut dyn FnMut(&mut Sequential), steps: usize) -> (f64, f64) {
        let mut rng = Pcg64::seeded(42);
        let mut model = Sequential::new(vec![Box::new(LinearMem::new(4, 2, None, &mut rng))]);
        let x = Tensor::from_vec(&[8, 4], (0..32).map(|i| ((i % 7) as f64) / 3.0 - 1.0).collect());
        let target = Tensor::from_vec(&[8, 2], (0..16).map(|i| ((i % 5) as f64) / 2.0).collect());
        let loss_of = |y: &Tensor| -> f64 {
            y.data.iter().zip(&target.data).map(|(a, b)| (a - b) * (a - b)).sum::<f64>()
        };
        let mut first = None;
        let mut last = 0.0;
        for _ in 0..steps {
            model.zero_grad();
            let y = model.forward(&x, true);
            last = loss_of(&y);
            first.get_or_insert(last);
            let grad = Tensor::from_vec(
                &y.shape,
                y.data.iter().zip(&target.data).map(|(a, b)| 2.0 * (a - b)).collect(),
            );
            model.backward(&grad);
            optim(&mut model);
        }
        (first.unwrap(), last)
    }

    #[test]
    fn sgd_reduces_loss() {
        let mut opt = Sgd::new(0.01, 0.9, 0.0);
        let (first, last) = fit(&mut |m| opt.step(m), 60);
        assert!(last < first * 0.2, "first={first} last={last}");
    }

    #[test]
    fn adam_reduces_loss() {
        let mut opt = Adam::new(0.05);
        let (first, last) = fit(&mut |m| opt.step(m), 80);
        assert!(last < first * 0.2, "first={first} last={last}");
    }

    #[test]
    fn weight_decay_shrinks_weights() {
        let mut rng = Pcg64::seeded(7);
        let mut model = Sequential::new(vec![Box::new(LinearMem::new(3, 3, None, &mut rng))]);
        let norm_before: f64 = {
            let mut n = 0.0;
            model.visit_params(&mut |p| n += p.value.iter().map(|v| v * v).sum::<f64>());
            n
        };
        let mut opt = Sgd::new(0.1, 0.0, 0.5);
        model.zero_grad();
        opt.step(&mut model);
        let norm_after: f64 = {
            let mut n = 0.0;
            model.visit_params(&mut |p| n += p.value.iter().map(|v| v * v).sum::<f64>());
            n
        };
        assert!(norm_after < norm_before);
    }
}

//! Training / evaluation loops (Fig 16's hardware-aware training: DPE
//! forward, full-precision backward, `update_weight()` after every
//! optimizer step so the arrays hold the freshly-quantized weights).

use super::loss::{accuracy, softmax_cross_entropy};
use super::optim::Sgd;
use super::Sequential;
use crate::arch::MappedModel;
use crate::data::Dataset;
use crate::tensor::Tensor;
use crate::util::rng::Pcg64;

/// Per-step training record (Fig 16 plots these curves).
#[derive(Debug, Clone)]
pub struct StepLog {
    pub step: usize,
    pub loss: f64,
    pub train_acc: f64,
}

/// Training configuration.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub batch_size: usize,
    pub steps: usize,
    pub lr: f64,
    pub momentum: f64,
    pub weight_decay: f64,
    pub seed: u64,
    /// Log every n steps.
    pub log_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            batch_size: 32,
            steps: 200,
            lr: 0.05,
            momentum: 0.9,
            weight_decay: 0.0,
            seed: 7,
            log_every: 10,
        }
    }
}

/// Assemble a batch tensor from dataset rows.
pub fn make_batch(data: &Dataset, idx: &[usize]) -> (Tensor, Vec<usize>) {
    let (feats, labels) = data.batch(idx);
    let mut shape = vec![idx.len()];
    shape.extend_from_slice(&data.sample_shape);
    (Tensor::from_vec(&shape, feats), labels)
}

/// SGD training loop. Returns the per-`log_every` step log.
pub fn train(model: &mut Sequential, data: &Dataset, cfg: &TrainConfig) -> Vec<StepLog> {
    let mut rng = Pcg64::new(cfg.seed, 0x7e41);
    let mut opt = Sgd::new(cfg.lr, cfg.momentum, cfg.weight_decay);
    let mut logs = Vec::new();
    let mut order: Vec<usize> = (0..data.len()).collect();
    rng.shuffle(&mut order);
    let mut cursor = 0usize;
    for step in 0..cfg.steps {
        if cursor + cfg.batch_size > order.len() {
            rng.shuffle(&mut order);
            cursor = 0;
        }
        let idx = &order[cursor..cursor + cfg.batch_size];
        cursor += cfg.batch_size;
        let (x, labels) = make_batch(data, idx);
        model.zero_grad();
        let logits = model.forward(&x, true);
        let (loss, grad) = softmax_cross_entropy(&logits, &labels);
        let acc = accuracy(&logits, &labels);
        model.backward(&grad);
        opt.step(model);
        // Refresh the hardware weight copies from the updated masters.
        model.update_weight();
        if step % cfg.log_every == 0 || step + 1 == cfg.steps {
            logs.push(StepLog { step, loss, train_acc: acc });
        }
    }
    logs
}

/// Accuracy over (a prefix of) a dataset for any forward function — the
/// one batching/accumulation loop behind [`evaluate`] and
/// [`evaluate_mapped`].
fn accuracy_over(
    data: &Dataset,
    batch: usize,
    limit: usize,
    mut forward: impl FnMut(&Tensor) -> Tensor,
) -> f64 {
    let n = data.len().min(limit);
    let mut correct = 0.0;
    let mut seen = 0usize;
    let mut i = 0;
    while i < n {
        let hi = (i + batch).min(n);
        let idx: Vec<usize> = (i..hi).collect();
        let (x, labels) = make_batch(data, &idx);
        let logits = forward(&x);
        correct += accuracy(&logits, &labels) * idx.len() as f64;
        seen += idx.len();
        i = hi;
    }
    correct / seen as f64
}

/// Evaluate classification accuracy over (a prefix of) a dataset.
pub fn evaluate(model: &mut Sequential, data: &Dataset, batch: usize, limit: usize) -> f64 {
    accuracy_over(data, batch, limit, |x| model.forward(x, false))
}

/// Evaluate classification accuracy of a chip-compiled model over (a
/// prefix of) a dataset, running each evaluation batch through the
/// micro-batched inference executor ([`MappedModel::infer_batched`]).
pub fn evaluate_mapped(
    model: &MappedModel,
    data: &Dataset,
    batch: usize,
    limit: usize,
    micro_batch: usize,
) -> f64 {
    accuracy_over(data, batch, limit, |x| model.infer_batched(x, micro_batch))
}

/// Mean loss over a dataset prefix (for test-loss curves).
pub fn evaluate_loss(model: &mut Sequential, data: &Dataset, batch: usize, limit: usize) -> f64 {
    let n = data.len().min(limit);
    let mut total = 0.0;
    let mut seen = 0usize;
    let mut i = 0;
    while i < n {
        let hi = (i + batch).min(n);
        let idx: Vec<usize> = (i..hi).collect();
        let (x, labels) = make_batch(data, &idx);
        let logits = model.forward(&x, false);
        let (loss, _) = softmax_cross_entropy(&logits, &labels);
        total += loss * idx.len() as f64;
        seen += idx.len();
        i = hi;
    }
    total / seen as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::mnist_like;
    use crate::nn::models::mlp;

    #[test]
    fn mlp_learns_digits_digital() {
        // The end-to-end signal: a digital MLP must learn the synthetic
        // digit task quickly.
        let data = mnist_like::load(512, 42);
        let (train_set, test_set) = data.split(448);
        let mut model = mlp(784, 64, 10, None, 1);
        let cfg = TrainConfig { steps: 120, batch_size: 32, lr: 0.1, ..Default::default() };
        let logs = train(&mut model, &train_set, &cfg);
        let first = logs.first().unwrap().loss;
        let last = logs.last().unwrap().loss;
        assert!(last < first * 0.5, "loss {first} -> {last}");
        let acc = evaluate(&mut model, &test_set, 32, 64);
        assert!(acc > 0.55, "test acc {acc}");
    }

    #[test]
    fn evaluate_handles_ragged_batches() {
        let data = mnist_like::load(10, 3);
        let mut model = mlp(784, 8, 10, None, 2);
        let acc = evaluate(&mut model, &data, 4, 10);
        assert!((0.0..=1.0).contains(&acc));
    }
}

//! Training / evaluation loops (Fig 16's hardware-aware training: DPE
//! forward, full-precision backward, `update_weight()` after every
//! optimizer step so the arrays hold the freshly-quantized weights).

use super::loss::{accuracy, softmax_cross_entropy};
use super::optim::Sgd;
use super::Sequential;
use crate::arch::MappedModel;
use crate::data::Dataset;
use crate::dpe::DeltaReport;
use crate::tensor::Tensor;
use crate::util::rng::Pcg64;
use std::time::Instant;

/// Per-step training record (Fig 16 plots these curves).
#[derive(Debug, Clone)]
pub struct StepLog {
    pub step: usize,
    pub loss: f64,
    pub train_acc: f64,
}

/// Training configuration.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub batch_size: usize,
    pub steps: usize,
    pub lr: f64,
    pub momentum: f64,
    pub weight_decay: f64,
    pub seed: u64,
    /// Log every n steps.
    pub log_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            batch_size: 32,
            steps: 200,
            lr: 0.05,
            momentum: 0.9,
            weight_decay: 0.0,
            seed: 7,
            log_every: 10,
        }
    }
}

/// Assemble a batch tensor from dataset rows.
pub fn make_batch(data: &Dataset, idx: &[usize]) -> (Tensor, Vec<usize>) {
    let (feats, labels) = data.batch(idx);
    let mut shape = vec![idx.len()];
    shape.extend_from_slice(&data.sample_shape);
    (Tensor::from_vec(&shape, feats), labels)
}

/// SGD training loop. Returns the per-`log_every` step log.
pub fn train(model: &mut Sequential, data: &Dataset, cfg: &TrainConfig) -> Vec<StepLog> {
    let mut rng = Pcg64::new(cfg.seed, 0x7e41);
    let mut opt = Sgd::new(cfg.lr, cfg.momentum, cfg.weight_decay);
    let mut logs = Vec::new();
    let mut order: Vec<usize> = (0..data.len()).collect();
    rng.shuffle(&mut order);
    let mut cursor = 0usize;
    for step in 0..cfg.steps {
        if cursor + cfg.batch_size > order.len() {
            rng.shuffle(&mut order);
            cursor = 0;
        }
        let idx = &order[cursor..cursor + cfg.batch_size];
        cursor += cfg.batch_size;
        let (x, labels) = make_batch(data, idx);
        model.zero_grad();
        let logits = model.forward(&x, true);
        let (loss, grad) = softmax_cross_entropy(&logits, &labels);
        let acc = accuracy(&logits, &labels);
        model.backward(&grad);
        opt.step(model);
        // Refresh the hardware weight copies from the updated masters.
        model.update_weight();
        if step % cfg.log_every == 0 || step + 1 == cfg.steps {
            logs.push(StepLog { step, loss, train_acc: acc });
        }
    }
    logs
}

/// What [`train_fast`] did and where the time went: the per-`log_every`
/// step log, cumulative wall-clock seconds per training phase, and the
/// merged delta-reprogramming counters across every step.
#[derive(Debug, Clone, Default)]
pub struct FastTrainReport {
    pub logs: Vec<StepLog>,
    /// Batch assembly (index gather into the reused buffers).
    pub batch_s: f64,
    /// Forward passes (DPE matmuls when hardware is bound).
    pub forward_s: f64,
    /// Backward passes (packed-kernel gradient GEMMs).
    pub backward_s: f64,
    /// Optimizer steps.
    pub optim_s: f64,
    /// Weight reprogramming (template-delta path).
    pub reprogram_s: f64,
    /// Merged [`DeltaReport`] over all steps and layers.
    pub delta: DeltaReport,
}

/// The fast hardware-aware training loop (Fig 16): identical batching,
/// shuffling, and update math to [`train`] — same seeds give the same
/// curve on any noise-free or digital model — but with the per-step
/// full-array reprogram replaced by template-delta reprogramming
/// ([`crate::nn::Layer::update_weight_delta`]), gradient GEMMs on the
/// packed register-tiled kernels, and batch buffers reused across steps.
/// On noisy engines the two loops are *statistically* equivalent but not
/// bit-identical: the delta path deliberately keeps the programmed noise
/// of unchanged cells instead of resampling every cell every step.
pub fn train_fast(model: &mut Sequential, data: &Dataset, cfg: &TrainConfig) -> FastTrainReport {
    let mut rng = Pcg64::new(cfg.seed, 0x7e41);
    let mut opt = Sgd::new(cfg.lr, cfg.momentum, cfg.weight_decay);
    let mut report = FastTrainReport::default();
    let mut order: Vec<usize> = (0..data.len()).collect();
    rng.shuffle(&mut order);
    let mut cursor = 0usize;
    // Batch buffers live across steps; the feature buffer round-trips
    // through the batch tensor and back, so steady state allocates nothing.
    let mut feats: Vec<f64> = Vec::new();
    let mut labels: Vec<usize> = Vec::new();
    let mut shape = vec![cfg.batch_size];
    shape.extend_from_slice(&data.sample_shape);
    for step in 0..cfg.steps {
        if cursor + cfg.batch_size > order.len() {
            rng.shuffle(&mut order);
            cursor = 0;
        }
        let idx = &order[cursor..cursor + cfg.batch_size];
        cursor += cfg.batch_size;
        let t = Instant::now();
        data.batch_into(idx, &mut feats, &mut labels);
        let x = Tensor::from_vec(&shape, std::mem::take(&mut feats));
        report.batch_s += t.elapsed().as_secs_f64();
        model.zero_grad();
        let t = Instant::now();
        let logits = model.forward(&x, true);
        report.forward_s += t.elapsed().as_secs_f64();
        let (loss, grad) = softmax_cross_entropy(&logits, &labels);
        let acc = accuracy(&logits, &labels);
        let t = Instant::now();
        model.try_backward(&grad).expect("forward(train=true) ran this step");
        report.backward_s += t.elapsed().as_secs_f64();
        let t = Instant::now();
        opt.step(model);
        report.optim_s += t.elapsed().as_secs_f64();
        // Refresh the arrays by delta: only blocks whose quantized digits
        // moved this step are redrawn (see `dpe::engine` §Perf).
        let t = Instant::now();
        report.delta.merge(&model.update_weight_delta());
        report.reprogram_s += t.elapsed().as_secs_f64();
        if step % cfg.log_every == 0 || step + 1 == cfg.steps {
            report.logs.push(StepLog { step, loss, train_acc: acc });
        }
        feats = x.data;
    }
    report
}

/// Accuracy over (a prefix of) a dataset for any forward function — the
/// one batching/accumulation loop behind [`evaluate`] and
/// [`evaluate_mapped`].
fn accuracy_over(
    data: &Dataset,
    batch: usize,
    limit: usize,
    mut forward: impl FnMut(&Tensor) -> Tensor,
) -> f64 {
    let n = data.len().min(limit);
    let mut correct = 0.0;
    let mut seen = 0usize;
    let mut i = 0;
    while i < n {
        let hi = (i + batch).min(n);
        let idx: Vec<usize> = (i..hi).collect();
        let (x, labels) = make_batch(data, &idx);
        let logits = forward(&x);
        correct += accuracy(&logits, &labels) * idx.len() as f64;
        seen += idx.len();
        i = hi;
    }
    correct / seen as f64
}

/// Evaluate classification accuracy over (a prefix of) a dataset.
pub fn evaluate(model: &mut Sequential, data: &Dataset, batch: usize, limit: usize) -> f64 {
    accuracy_over(data, batch, limit, |x| model.forward(x, false))
}

/// Evaluate classification accuracy of a chip-compiled model over (a
/// prefix of) a dataset, running each evaluation batch through the
/// micro-batched inference executor ([`MappedModel::infer_batched`]).
pub fn evaluate_mapped(
    model: &MappedModel,
    data: &Dataset,
    batch: usize,
    limit: usize,
    micro_batch: usize,
) -> f64 {
    accuracy_over(data, batch, limit, |x| model.infer_batched(x, micro_batch))
}

/// Mean loss over a dataset prefix (for test-loss curves).
pub fn evaluate_loss(model: &mut Sequential, data: &Dataset, batch: usize, limit: usize) -> f64 {
    let n = data.len().min(limit);
    let mut total = 0.0;
    let mut seen = 0usize;
    let mut i = 0;
    while i < n {
        let hi = (i + batch).min(n);
        let idx: Vec<usize> = (i..hi).collect();
        let (x, labels) = make_batch(data, &idx);
        let logits = model.forward(&x, false);
        let (loss, _) = softmax_cross_entropy(&logits, &labels);
        total += loss * idx.len() as f64;
        seen += idx.len();
        i = hi;
    }
    total / seen as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::mnist_like;
    use crate::nn::models::mlp;

    #[test]
    fn mlp_learns_digits_digital() {
        // The end-to-end signal: a digital MLP must learn the synthetic
        // digit task quickly.
        let data = mnist_like::load(512, 42);
        let (train_set, test_set) = data.split(448);
        let mut model = mlp(784, 64, 10, None, 1);
        let cfg = TrainConfig { steps: 120, batch_size: 32, lr: 0.1, ..Default::default() };
        let logs = train(&mut model, &train_set, &cfg);
        let first = logs.first().unwrap().loss;
        let last = logs.last().unwrap().loss;
        assert!(last < first * 0.5, "loss {first} -> {last}");
        let acc = evaluate(&mut model, &test_set, 32, 64);
        assert!(acc > 0.55, "test acc {acc}");
    }

    #[test]
    fn evaluate_handles_ragged_batches() {
        let data = mnist_like::load(10, 3);
        let mut model = mlp(784, 8, 10, None, 2);
        let acc = evaluate(&mut model, &data, 4, 10);
        assert!((0.0..=1.0).contains(&acc));
    }

    #[test]
    fn train_fast_curve_bit_identical_digital() {
        // Same seeds, same data: the fast loop must reproduce the legacy
        // loop's training curve bit for bit on a digital model.
        let data = mnist_like::load(128, 11);
        let mut legacy = mlp(784, 16, 10, None, 5);
        let mut fast = mlp(784, 16, 10, None, 5);
        let cfg = TrainConfig { steps: 12, batch_size: 16, lr: 0.1, log_every: 1, ..Default::default() };
        let logs = train(&mut legacy, &data, &cfg);
        let rep = train_fast(&mut fast, &data, &cfg);
        assert_eq!(logs.len(), rep.logs.len());
        for (a, b) in logs.iter().zip(&rep.logs) {
            assert_eq!(a.step, b.step);
            assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "loss @ step {}", a.step);
            assert_eq!(a.train_acc.to_bits(), b.train_acc.to_bits(), "acc @ step {}", a.step);
        }
    }

    #[test]
    fn train_fast_curve_bit_identical_noise_free_hw() {
        // On a noise-free engine the delta reprogram lands on exactly the
        // digits a full reprogram writes, so even the hardware-in-the-loop
        // curve is bit-identical between the two loops.
        use crate::dpe::{DotProductEngine, SliceMethod, SliceSpec};
        use crate::nn::HwSpec;
        let data = mnist_like::load(96, 13);
        let hw = || {
            HwSpec::uniform(
                DotProductEngine::ideal((64, 64)),
                SliceMethod::int(SliceSpec::int8()),
            )
        };
        let mut legacy = mlp(784, 16, 10, Some(hw()), 6);
        let mut fast = mlp(784, 16, 10, Some(hw()), 6);
        let cfg = TrainConfig { steps: 8, batch_size: 16, lr: 0.05, log_every: 1, ..Default::default() };
        let logs = train(&mut legacy, &data, &cfg);
        let rep = train_fast(&mut fast, &data, &cfg);
        for (a, b) in logs.iter().zip(&rep.logs) {
            assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "loss @ step {}", a.step);
        }
        // The delta path actually engaged: the first step per core seeds
        // the template with a full program, later steps classify blocks.
        assert!(rep.delta.full_reprograms >= 1, "first delta call seeds the template");
        assert!(rep.delta.full_reprograms < cfg.steps * 2, "later steps must run the delta path");
        assert_eq!(
            rep.delta.blocks_clean + rep.delta.dirty_blocks(),
            rep.delta.blocks,
            "every block is classified exactly once per step"
        );
    }
}

//! Quickstart: variable-precision DPE matmuls in a few lines.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use memintelli::dpe::{DotProductEngine, DpeConfig, SliceMethod, SliceSpec};
use memintelli::tensor::Matrix;
use memintelli::util::rng::Pcg64;

fn main() {
    // 1. Make some FP64 operands.
    let mut rng = Pcg64::seeded(42);
    let a = Matrix::random_normal(128, 128, 0.0, 1.0, &mut rng);
    let b = Matrix::random_normal(128, 128, 0.0, 1.0, &mut rng);
    let ideal = a.matmul(&b);

    // 2. A hardware engine with Table-2 defaults: 64×64 arrays, 16
    //    conductance levels, 5% variation, 8-bit DAC / 10-bit ADC.
    let engine = DotProductEngine::new(DpeConfig::default(), 42);

    // 3. Multiply at different precisions (paper Fig 11).
    for (name, method) in [
        ("INT4  (1,1,2)       quantize", SliceMethod::int(SliceSpec::int4())),
        ("INT8  (1,1,2,4)     quantize", SliceMethod::int(SliceSpec::int8())),
        ("BF16  (1,1,2,4)     prealign", SliceMethod::fp(SliceSpec::bf16())),
        ("FP16  (1,1,2,4,4)   prealign", SliceMethod::fp(SliceSpec::fp16())),
        ("FP32  (1,1,2,4,4,…) prealign", SliceMethod::fp(SliceSpec::fp32())),
    ] {
        let c = engine.matmul(&a, &b, &method, &method);
        println!("{name}:  relative error = {:.3e}", c.relative_error(&ideal));
    }

    // 4. Weight reuse (the NN hot path): program once, run many inputs.
    let method = SliceMethod::int(SliceSpec::int8());
    let w = engine.prepare_weights(&b, &method, 0);
    println!(
        "\nprepared weights: {} physical 64x64 arrays for a 128x128 INT8 matrix",
        w.arrays_used()
    );
    for i in 0..3 {
        let x = Matrix::random_normal(4, 128, 0.0, 1.0, &mut rng);
        let y = engine.matmul_prepared(&x, &w, &method, 0);
        println!("batch {i}: out norm {:.3} (RE vs ideal {:.3e})",
            y.frobenius(), y.relative_error(&x.matmul(&b)));
    }
}

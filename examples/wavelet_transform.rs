//! Continuous wavelet transform of an ENSO-like sea-surface-temperature
//! series with INT4-mapped Morlet kernels (paper Fig 14).
//!
//! ```bash
//! cargo run --release --example wavelet_transform
//! ```

use memintelli::apps::cwt::{int4_method, scale_ladder, CwtProcessor};
use memintelli::data::nino;
use memintelli::dpe::{DotProductEngine, DpeConfig};

fn main() {
    // Monthly ENSO-like anomaly series (offline NINO3 substitute).
    let signal = nino::load(1024, 2024);
    println!("signal: {} monthly samples, mean {:.3}", signal.len(),
        signal.iter().sum::<f64>() / signal.len() as f64);

    let scales = scale_ladder(4.0, 128.0, 4);
    let proc = CwtProcessor::new(192, scales.clone());

    let digital = proc.power(&signal, None);
    let engine = DotProductEngine::new(DpeConfig::default(), 3);
    let method = int4_method();
    let hardware = proc.power(&signal, Some((&engine, &method)));

    // ASCII rendering of the mean power per scale (the banded structure of
    // Fig 14(d): seasonal ~12 months + ENSO band ~30–60 months).
    println!("\nmean CWT power per scale (digital | INT4 hardware):");
    let max_p = (0..scales.len())
        .map(|s| digital.row(s).iter().sum::<f64>() / digital.cols as f64)
        .fold(0.0f64, f64::max);
    for (si, &s) in scales.iter().enumerate() {
        let md = digital.row(si).iter().sum::<f64>() / digital.cols as f64;
        let mh = hardware.row(si).iter().sum::<f64>() / hardware.cols as f64;
        let bar_d = "#".repeat((md / max_p * 40.0) as usize);
        let bar_h = "+".repeat((mh / max_p * 40.0) as usize);
        println!("  {s:>6.1} mo | {bar_d:<40} | {bar_h:<40}");
    }

    // Agreement metric.
    let n = digital.data.len() as f64;
    let (ma, mb) = (
        digital.data.iter().sum::<f64>() / n,
        hardware.data.iter().sum::<f64>() / n,
    );
    let cov: f64 = digital.data.iter().zip(&hardware.data).map(|(x, y)| (x - ma) * (y - mb)).sum();
    let va: f64 = digital.data.iter().map(|x| (x - ma) * (x - ma)).sum();
    let vb: f64 = hardware.data.iter().map(|y| (y - mb) * (y - mb)).sum();
    println!("\npearson(digital, hardware) = {:.4}", cov / (va.sqrt() * vb.sqrt()));
}

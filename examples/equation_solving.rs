//! Linear equation solving on memristive hardware (paper Fig 13).
//!
//! Models a resistive word line as a band SPD system and solves it with
//! conjugate gradients whose matvec runs on the DPE, comparing software
//! and hardware convergence.
//!
//! ```bash
//! cargo run --release --example equation_solving
//! ```

use memintelli::apps::solver::{conjugate_gradient, wordline_equation, MatvecBackend};
use memintelli::dpe::engine::AdcPolicy;
use memintelli::dpe::{DotProductEngine, DpeConfig, SliceMethod, SliceSpec};
use memintelli::util::rng::Pcg64;

fn main() {
    let n = 48;
    let mut rng = Pcg64::seeded(7);
    let g_load: Vec<f64> = (0..n).map(|_| rng.uniform_range(1e-6, 1e-5)).collect();
    let (a, b) = wordline_equation(&g_load, 2.93, 0.2);
    println!("word-line circuit equation: {n} nodes, Rw = 2.93 Ω, Vin = 0.2 V\n");

    let sw = conjugate_gradient(&a, &b, &MatvecBackend::Software, 1e-10, 400);
    println!("software CG : {} iterations, final residual {:.2e}",
        sw.residuals.len(), sw.residuals.last().unwrap());

    let mut cfg = DpeConfig { array: (32, 32), adc_policy: AdcPolicy::Calibrated, ..DpeConfig::default() };
    cfg.device.cv = 0.02;
    let engine = DotProductEngine::new(cfg, 7);
    let method = SliceMethod::fp(SliceSpec::solver26());
    let backend = MatvecBackend::hardware(&engine, method, &a);
    let hw = conjugate_gradient(&a, &b, &backend, 1e-6, 400);
    println!("hardware CG : {} iterations, best residual {:.2e}",
        hw.residuals.len(),
        hw.residuals.iter().cloned().fold(f64::INFINITY, f64::min));

    println!("\nresidual curves (software vs hardware):");
    for i in (0..sw.residuals.len().max(hw.residuals.len())).step_by(4) {
        let s = sw.residuals.get(i).map(|r| format!("{r:.2e}")).unwrap_or_else(|| "-".into());
        let h = hw.residuals.get(i).map(|r| format!("{r:.2e}")).unwrap_or_else(|| "-".into());
        println!("  iter {i:>3}: sw {s:>10}   hw {h:>10}");
    }

    let maxdv = hw.x.iter().zip(&sw.x).map(|(h, s)| (h - s).abs()).fold(0.0f64, f64::max);
    println!("\nnode voltages (first 8): ");
    for i in 0..8 {
        println!("  V[{i}]  sw {:.6}  hw {:.6}", sw.x[i], hw.x[i]);
    }
    println!("\nmax |V_hw − V_sw| = {maxdv:.2e} V (drive 0.2 V) — Fig 13(c): highly consistent");
}

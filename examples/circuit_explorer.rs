//! Crossbar circuit exploration (paper Fig 10): IR-drop along word lines,
//! attenuation of output currents, solver convergence, and the Elmore
//! settling estimate from parasitic capacitance.
//!
//! ```bash
//! cargo run --release --example circuit_explorer [--size N] [--rwire OHM]
//! ```

use memintelli::circuit::CrossbarCircuit;
use memintelli::tensor::Matrix;
use memintelli::util::rng::Pcg64;

fn flag(name: &str, default: f64) -> f64 {
    std::env::args()
        .skip_while(|a| a != name)
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

fn main() {
    let n = flag("--size", 64.0) as usize;
    let r_wire = flag("--rwire", 2.93);
    let mut rng = Pcg64::seeded(11);
    let g = Matrix::random_uniform(n, n, 1e-7, 1e-5, &mut rng);
    let xb = CrossbarCircuit::new(g, r_wire);

    // Sinusoidal drive on the word lines (Fig 10(a)).
    let v_in: Vec<f64> = (0..n).map(|i| 0.1 + 0.1 * (i as f64 / 6.0).sin().abs()).collect();

    let t0 = std::time::Instant::now();
    let (sol, stats) = xb.solve_cross_iteration(&v_in, 1e-3 * 0.2, 20);
    let dt = t0.elapsed();
    println!("{n}x{n} array, Rw = {r_wire} Ω");
    println!("cross-iteration: {} sweeps, final Δ {:.2e}, {:?}", stats.iterations,
        stats.deltas.last().unwrap(), dt);

    // Voltage attenuation along the first word line (Fig 10(b)).
    println!("\nword-line voltage profile (row 0, drive {:.3} V):", v_in[0]);
    for j in (0..n).step_by((n / 8).max(1)) {
        let v = sol.v_word.at(0, j);
        let bar = "#".repeat((v / v_in[0] * 50.0) as usize);
        println!("  col {j:>4}: {v:.4} V  {bar}");
    }

    // Current attenuation vs ideal (Fig 10(c)).
    let ideal = xb.ideal_currents(&v_in);
    let att: Vec<f64> = sol.i_out.iter().zip(&ideal).map(|(s, i)| s / i).collect();
    let mean_att = att.iter().sum::<f64>() / att.len() as f64;
    println!("\nmean I_out/I_ideal = {mean_att:.4} (IR-drop loss {:.1}%)", (1.0 - mean_att) * 100.0);

    // Direct solve cross-check for small arrays.
    if n <= 128 {
        let direct = xb.solve_direct(&v_in).unwrap();
        let re: f64 = sol
            .i_out
            .iter()
            .zip(&direct.i_out)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt()
            / direct.i_out.iter().map(|v| v * v).sum::<f64>().sqrt();
        println!("vs banded-LU direct solve: RE {re:.2e}");
    }

    println!("Elmore settling estimate: {:.2e} s", xb.elmore_delay());
}

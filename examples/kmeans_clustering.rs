//! K-means clustering of IRIS on the DPE with the dot-product Euclidean
//! distance trick (paper Fig 15).
//!
//! ```bash
//! cargo run --release --example kmeans_clustering
//! ```

use memintelli::apps::kmeans::{
    clustering_accuracy, int8_method, kmeans, min_max_normalize, KmeansConfig,
};
use memintelli::data::iris;
use memintelli::dpe::{DotProductEngine, DpeConfig};
use memintelli::tensor::Matrix;

fn main() {
    let ds = iris::load(50, 42);
    let mut x = Matrix::from_vec(ds.len(), 4, ds.features.clone());
    min_max_normalize(&mut x);
    println!("IRIS-like data: {} samples, 3 classes, features normalized to [0,1]\n", ds.len());

    let cfg = KmeansConfig::default(); // k=3, tail n=10, INT8 (1,1,2,4)

    let digital = kmeans(&x, &cfg, None);
    let acc_d = clustering_accuracy(&digital.assignments, &ds.labels, 3);
    println!("digital  : {} iterations, accuracy {:.3}", digital.iterations, acc_d);

    let mut dpe_cfg = DpeConfig::default();
    dpe_cfg.device.cv = 0.02;
    let engine = DotProductEngine::new(dpe_cfg, 3);
    let method = int8_method();
    let hw = kmeans(&x, &cfg, Some((&engine, &method)));
    let acc_h = clustering_accuracy(&hw.assignments, &ds.labels, 3);
    let agree = clustering_accuracy(&hw.assignments, &digital.assignments, 3);
    println!("hardware : {} iterations, accuracy {:.3}, agreement with digital {:.3}",
        hw.iterations, acc_h, agree);

    // Fig 15(a): center evolution.
    println!("\ncenter evolution on hardware (feature 3 = petal width):");
    for (it, centers) in hw.center_history.iter().enumerate().step_by(2) {
        let vals: Vec<String> = (0..3).map(|c| format!("{:.3}", centers.at(c, 3))).collect();
        println!("  iter {it:>2}: [{}]", vals.join(", "));
    }

    // Fig 15(b): cluster sizes.
    let mut counts = [0usize; 3];
    for &a in &hw.assignments {
        counts[a] += 1;
    }
    println!("\ncluster sizes (hardware): {counts:?} — ground truth is [50, 50, 50]");
}

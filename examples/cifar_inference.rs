//! CIFAR-scale inference under hardware non-idealities (paper Fig 17):
//! train a small ResNet digitally, convert to hardware layers
//! (`load_state_dict` + `update_weight()` flow), and sweep slice bits and
//! conductance variation.
//!
//! ```bash
//! cargo run --release --example cifar_inference
//! ```

use memintelli::data::cifar_like;
use memintelli::dpe::{DotProductEngine, DpeConfig, SliceMethod, SliceSpec};
use memintelli::nn::models::resnet18_cifar;
use memintelli::nn::train::{evaluate, train, TrainConfig};
use memintelli::nn::HwSpec;

fn main() {
    let width = 4; // CIFAR-scale width multiplier (64 = full ResNet-18)
    let data = cifar_like::load(640, 7);
    let (train_set, test_set) = data.split(512);

    // 1. Train digitally (fast full-precision path).
    let mut digital = resnet18_cifar(width, None, 7);
    let cfg = TrainConfig { steps: 60, batch_size: 16, lr: 0.02, log_every: 20, seed: 7, ..Default::default() };
    println!("training ResNet-18(w={width}) digitally on synthetic CIFAR…");
    let logs = train(&mut digital, &train_set, &cfg);
    println!("  final train loss {:.3}", logs.last().unwrap().loss);
    let acc_digital = evaluate(&mut digital, &test_set, 16, 96);
    println!("  digital test accuracy: {acc_digital:.3}\n");

    // 2. Transfer the trained state into hardware models and sweep
    //    configurations (`load_state_dict` + `update_weight()` flow).
    let mut to_hw = |hw: HwSpec| {
        let mut m = resnet18_cifar(width, Some(hw), 7);
        m.load_state_from(&mut digital);
        m.update_weight(); // re-quantize + program the arrays
        m
    };

    println!("accuracy vs number of 1-bit slices (Fig 17a):");
    for bits in [3usize, 4, 5, 6, 8] {
        let mut dpe = DpeConfig::default();
        dpe.device.cv = 0.01;
        let hw = HwSpec::uniform(DotProductEngine::new(dpe, 7), SliceMethod::int(SliceSpec::ones(bits)));
        let mut m = to_hw(hw);
        println!("  {bits} bits: {:.3}", evaluate(&mut m, &test_set, 16, 96));
    }

    println!("\naccuracy vs conductance variation at INT8 (Fig 17b):");
    for cv in [0.0, 0.02, 0.05, 0.1] {
        let mut dpe = DpeConfig::default();
        dpe.device.cv = cv;
        let hw = HwSpec::uniform(DotProductEngine::new(dpe, 7), SliceMethod::int(SliceSpec::int8()));
        let mut m = to_hw(hw);
        println!("  cv={cv:<5}: {:.3}", evaluate(&mut m, &test_set, 16, 96));
    }
}

//! END-TO-END DRIVER — LeNet-5 hardware-aware training (paper Fig 16).
//!
//! Proves all layers compose on a real small workload: generates a digit
//! dataset, trains LeNet-5 with the DPE forward path (INT8 sliced, noisy,
//! ADC-quantized) and full-precision backward, logs the loss curve, then
//! evaluates the trained model both on the native engine and — when the
//! AOT artifacts are built — through the fused Pallas/XLA forward
//! executable via PJRT (Python never runs here).
//!
//! ```bash
//! make artifacts && cargo run --release --example lenet_training [--steps N]
//! ```
//!
//! The run recorded in EXPERIMENTS.md §End-to-end used `--steps 300`.

use memintelli::coordinator::experiments::lenet_params_f32;
use memintelli::data::mnist_like;
use memintelli::dpe::{DotProductEngine, DpeConfig, SliceMethod, SliceSpec};
use memintelli::nn::loss::accuracy;
use memintelli::nn::models::lenet5;
use memintelli::nn::train::{evaluate, make_batch, train, TrainConfig};
use memintelli::nn::HwSpec;
use memintelli::runtime::{Runtime, XlaDpe};
use memintelli::tensor::Tensor;

fn main() -> anyhow::Result<()> {
    let steps: usize = std::env::args()
        .skip_while(|a| a != "--steps")
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(120);

    // Dataset: deterministic procedural digits (offline MNIST substitute).
    let data = mnist_like::load(2048, 2024);
    let (train_set, test_set) = data.split(1792);
    println!("dataset: {} train / {} test, 10 classes", train_set.len(), test_set.len());

    // Hardware binding: INT8 (1,1,2,4), Table-2 device, 64×64 arrays.
    let hw = HwSpec::uniform(
        DotProductEngine::new(DpeConfig::default(), 2024),
        SliceMethod::int(SliceSpec::int8()),
    );
    let mut model = lenet5(Some(hw), 2024);
    println!("model: LeNet-5 on DPE layers, {} parameters\n", model.num_params());

    // Train: DPE forward, full-precision straight-through backward.
    let cfg = TrainConfig {
        steps,
        batch_size: 32,
        lr: 0.05,
        momentum: 0.9,
        log_every: (steps / 15).max(1),
        seed: 2024,
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    let logs = train(&mut model, &train_set, &cfg);
    let train_time = t0.elapsed().as_secs_f64();

    println!("loss curve (hardware-aware INT8 training):");
    for l in &logs {
        let bar = "#".repeat((l.loss * 20.0).min(60.0) as usize);
        println!("  step {:>4}  loss {:.4}  train acc {:.3}  {bar}", l.step, l.loss, l.train_acc);
    }
    println!("\ntrained {steps} steps in {train_time:.1} s ({:.2} steps/s)", steps as f64 / train_time);

    let test_acc = evaluate(&mut model, &test_set, 32, 256);
    println!("test accuracy (native DPE forward): {test_acc:.3}");

    // Cross-check through the AOT Pallas/XLA fused forward, if built.
    let rt = Runtime::cpu("artifacts")?;
    let xd = XlaDpe::new(rt);
    if xd.runtime().has_artifact("lenet_fwd_b32_int8") {
        let idx: Vec<usize> = (0..32).collect();
        let (x, labels) = make_batch(&test_set, &idx);
        let xf: Vec<f32> = x.data.iter().map(|&v| v as f32).collect();
        let params = lenet_params_f32(&mut model);
        let logits = xd.lenet_forward(32, "int8", false, &xf, &params, 7)?;
        let acc_xla = accuracy(&Tensor::from_matrix(&logits), &labels);
        let native_logits = model.forward(&x, false);
        let acc_native = accuracy(&native_logits, &labels);
        println!("batch of 32 — native acc {acc_native:.3} vs XLA(AOT pallas) acc {acc_xla:.3}");
        println!("(both backends run the same bit-sliced DPE; Python is not involved at runtime)");
    } else {
        println!("artifacts not built — run `make artifacts` for the XLA cross-check");
    }
    Ok(())
}
